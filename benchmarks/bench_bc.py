"""Paper Figures 5/7/9: BC performance + efficiency vs place count,
BC-G (GLB) vs BC (static partitioning, the legacy baseline).

The paper's y-axis is edges traversed per second; we report BFS-sweep
throughput (sweeps = the unit `process` budget counts) and superstep
efficiency, plus wall time. The R-MAT graph is replicated (paper's
assumption) and sources are statically partitioned, GLB rebalances.
"""
import time

import numpy as np

from repro.core import GLBParams, run_sim
from repro.problems.bc import bc_problem
from repro.problems.rmat import rmat_graph

PLACES = (1, 2, 4, 8, 16)
SCALE = 6


def run():
    rows = []
    adj, n = rmat_graph(scale=SCALE, seed=7)
    edges = int(adj.sum())
    for variant, params in (
        ("bc_g", GLBParams(n=4, w=2, steal_k=16)),
        ("bc_static", GLBParams(n=4, no_steal=True)),
    ):
        base = None
        for P in PLACES:
            prob = bc_problem(adj, capacity=512)
            t0 = time.time()
            out = run_sim(prob, P, params, seed=0)
            dt = time.time() - t0
            steps = int(out.supersteps)
            work = np.asarray(out.stats["processed"], np.float64)
            if base is None:
                base = steps  # P=1 makespan
            speedup = base / steps
            rows.append((
                f"{variant}_p{P}",
                dt / max(steps, 1) * 1e6,
                f"steps={steps};speedup={speedup:.2f};"
                f"edges_sweeps_s={edges*work.sum()/n/dt:.0f};"
                f"work_std={work.std():.2f}",
            ))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
