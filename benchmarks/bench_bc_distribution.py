"""Paper Figures 6/8/10: workload distribution, BC vs BC-G.

The paper bar-plots per-place calculation time and reports mean/std:
BG/Q std 4.027 -> 1.141; Power 775 std 58.463 -> 1.482, with BC-G's
makespan within 1.5% of the mean. We reproduce both metrics on (a) the
paper's own degenerate-imbalance construction (§2.6.1) and (b) an R-MAT
graph, on 8 places.
"""
import time

import numpy as np

from repro.core import GLBParams, run_sim
from repro.problems.bc import bc_problem
from repro.problems.rmat import rmat_graph

P = 8


def _case(name, adj):
    rows = []
    prob = bc_problem(adj, capacity=512)
    res = {}
    for variant, params in (
        ("static", GLBParams(n=4, no_steal=True)),
        ("glb", GLBParams(n=4, w=2, steal_k=16)),
    ):
        t0 = time.time()
        out = run_sim(prob, P, params, seed=0)
        dt = time.time() - t0
        w = np.asarray(out.stats["processed"], np.float64)
        res[variant] = (w, int(out.supersteps))
        rows.append((
            f"bc_dist_{name}_{variant}",
            dt / max(int(out.supersteps), 1) * 1e6,
            f"work_mean={w.mean():.1f};work_std={w.std():.3f};"
            f"makespan={int(out.supersteps)}",
        ))
    w_s, ms_s = res["static"]
    w_g, ms_g = res["glb"]
    # the paper's headline: GLB makespan ~= mean of static per-place time
    rows.append((
        f"bc_dist_{name}_summary", 0.0,
        f"std_reduction={w_s.std()/max(w_g.std(),1e-9):.1f}x;"
        f"makespan_vs_mean={ms_g/max(w_s.mean()/1,1e-9):.3f};"
        f"makespan_speedup={ms_s/ms_g:.2f}x",
    ))
    return rows


def run():
    rows = []
    # (a) the paper's degenerate case: path graph, cost(v) ~ N - v
    n = 96
    path = np.zeros((n, n), np.float32)
    path[np.arange(n - 1), np.arange(1, n)] = 1.0
    rows += _case("path", path)
    # (b) R-MAT
    adj, _ = rmat_graph(scale=6, seed=3)
    rows += _case("rmat", adj)
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
