"""Kernel micro-benchmarks: jnp oracle wall times on CPU (what actually
executes here) + correctness deltas vs the Pallas kernels in interpret
mode. TPU-side performance is covered by the roofline artifacts
(EXPERIMENTS.md §Roofline), not CPU timing.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_decode import flash_decode
from repro.kernels.uts_expand import uts_expand
from repro.problems.uts import geom_thresholds


def _timeit(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters * 1e6


def run():
    rows = []
    ks = jax.random.split(jax.random.key(0), 5)

    # attention: ref vs chunked (the deployable long-seq path)
    q = jax.random.normal(ks[0], (2, 1024, 8, 64), jnp.float32)
    k = jax.random.normal(ks[1], (2, 1024, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (2, 1024, 2, 64), jnp.float32)
    f_ref = jax.jit(lambda q, k, v: ref.attention_ref(q, k, v))
    f_chk = jax.jit(lambda q, k, v: ref.attention_chunked(q, k, v))
    us_ref = _timeit(f_ref, q, k, v)
    us_chk = _timeit(f_chk, q, k, v)
    err = float(jnp.abs(f_ref(q, k, v) - f_chk(q, k, v)).max())
    rows.append(("attn_ref_1k", us_ref, "impl=full"))
    rows.append(("attn_chunked_1k", us_chk, f"impl=flash_jnp;err={err:.1e}"))

    # pallas flash (interpret) correctness on one shape
    out = flash_attention(q[:, :256], k[:, :256], v[:, :256], causal=True,
                          interpret=True, block_q=64, block_k=64)
    want = ref.attention_ref(q[:, :256], k[:, :256], v[:, :256])
    rows.append(("attn_pallas_interp", 0.0,
                 f"err={float(jnp.abs(out-want).max()):.1e}"))

    # flash decode (split-KV, interpret) vs the windowed oracle, plus the
    # CPU-deployable masked-window jnp path's wall time
    qd = jax.random.normal(ks[3], (4, 1, 8, 64), jnp.float32)
    kc = jax.random.normal(ks[4], (4, 512, 2, 64), jnp.float32)
    vc = jax.random.normal(ks[0], (4, 512, 2, 64), jnp.float32)
    lens = jnp.asarray([512, 333, 64, 1], jnp.int32)
    dec = flash_decode(qd, kc, vc, lens, block_k=128, interpret=True)
    derr = 0.0
    for i, L in enumerate(np.asarray(lens)):
        want = ref.attention_ref(qd[i:i + 1], kc[i:i + 1, :L],
                                 vc[i:i + 1, :L], causal=True)
        derr = max(derr, float(jnp.abs(dec[i:i + 1] - want).max()))
    rows.append(("flash_decode_interp", 0.0, f"err={derr:.1e}"))
    f_dec = jax.jit(lambda q, k, v, l: ref.decode_ref(q, k, v, l))
    us_dec = _timeit(f_dec, qd, kc, vc, lens)
    rows.append(("decode_ref_b4_s512", us_dec, "impl=masked_jnp"))

    # ssd: sequential scan vs chunk-matmul form
    x = jax.random.normal(ks[3], (2, 512, 4, 64), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[4], (2, 512, 4))) * 0.1
    A = -jnp.ones((4,))
    B = jax.random.normal(ks[0], (2, 512, 64))
    C = jax.random.normal(ks[1], (2, 512, 64))
    f_scan = jax.jit(lambda *a: ref.ssd_ref(*a)[0])
    f_chunk = jax.jit(lambda *a: ref.ssd_chunked_ref(*a)[0])
    us_scan = _timeit(f_scan, x, dt, A, B, C)
    us_chunk = _timeit(f_chunk, x, dt, A, B, C)
    err = float(jnp.abs(f_scan(x, dt, A, B, C)
                        - f_chunk(x, dt, A, B, C)).max())
    rows.append(("ssd_scan_512", us_scan, "impl=sequential"))
    rows.append(("ssd_chunked_512", us_chunk,
                 f"impl=chunk_matmul;err={err:.1e};"
                 f"speedup={us_scan/us_chunk:.1f}x"))

    # uts_expand: jnp ref vs pallas interpret equality
    thr = jnp.asarray(geom_thresholds(4.0))
    d0 = jnp.arange(128, dtype=jnp.uint32) * 7919
    d1 = jnp.arange(128, dtype=jnp.uint32) * 104729
    base = jnp.zeros(128, jnp.int32)
    f_exp = jax.jit(lambda *a: ref.uts_expand_ref(*a, 64)[2])
    us_exp = _timeit(f_exp, d0, d1, base, thr)
    pk = uts_expand(d0, d1, base, thr, width=64, interpret=True)
    rk = ref.uts_expand_ref(d0, d1, base, thr, 64)
    same = all(np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(pk, rk))
    rows.append(("uts_expand_128x64", us_exp, f"pallas_bitexact={same}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
