"""GLB-MoE: the paper's workload-distribution metric applied to expert
parallelism. Skewed router load (zipf over experts) -> per-rank load std
before/after the lifeline rebalancer, plus drop-rate impact at fixed
capacity.
"""
import time

import numpy as np

from repro.models.glb_moe import glb_expert_rebalance


def run():
    rows = []
    rng = np.random.default_rng(0)
    for E, R, tag in ((64, 16, "moonshot64e_16r"), (16, 8, "phi16e_8r")):
        # zipf-skewed expert popularity, as observed in real routers
        pop = 1.0 / (np.arange(E) + 1) ** 1.1
        counts = rng.multinomial(100_000, pop / pop.sum()).astype(float)
        perm = np.arange(E)
        t0 = time.time()
        res = glb_expert_rebalance(counts, perm, n_ranks=R, rounds=16)
        us = (time.time() - t0) * 1e6
        rows.append((
            f"moe_glb_{tag}", us,
            f"load_std_before={res.loads_before.std():.0f};"
            f"load_std_after={res.loads_after.std():.0f};"
            f"max_before={res.loads_before.max():.0f};"
            f"max_after={res.loads_after.max():.0f};"
            f"swaps={len(res.swaps)}",
        ))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
