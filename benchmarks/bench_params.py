"""Paper §2.4: the w / z / n tuning space.

"It is more likely to steal from a random victim with larger w ... larger n
means more tasks before responding" — we sweep each knob on UTS and report
supersteps (makespan), idle fraction and steal mix, the quantities the
paper's GLB log exposes for tuning.
"""
import time

import numpy as np

from repro.core import GLBParams, run_sim
from repro.problems.uts import uts_problem

P = 16
DEPTH = 8


def _one(tag, params):
    prob = uts_problem(4.0, DEPTH, 19)
    t0 = time.time()
    out = run_sim(prob, P, params, seed=0)
    dt = time.time() - t0
    st = {k: np.asarray(v, np.float64) for k, v in out.stats.items()}
    steps = int(out.supersteps)
    idle = st["idle_steps"].sum() / max(steps * P, 1)
    return (
        f"params_{tag}",
        dt / max(steps, 1) * 1e6,
        f"steps={steps};idle_frac={idle:.3f};"
        f"rand={int(st['steals_random'].sum())};"
        f"life={int(st['steals_lifeline'].sum())}",
    )


def run():
    rows = []
    for w in (0, 1, 2, 4, 8):
        rows.append(_one(f"w{w}", GLBParams(n=64, w=w, steal_k=32)))
    for z in (1, 2, 4):
        rows.append(_one(f"z{z}", GLBParams(n=64, w=1, z=z, steal_k=32)))
    for n in (16, 64, 256, 1024):
        rows.append(_one(f"n{n}", GLBParams(n=n, w=2, steal_k=32)))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
