"""Serving engine throughput: tokens/s and host syncs per token for the
legacy per-token decode loop vs the jitted multi-step ``lax.fori_loop``
engine (on-device sampling, one host drain per N positions), plus the
paged KV pool vs contiguous slots — same-workload tokens/s and max
concurrent sequences at fixed cache memory (the paged packing win) —
plus the PR 4 policy layer: the shared-system-prompt workload (radix
prefix cache: hit rate and prefill tokens saved) and TTFT p50/p99 for
short requests arriving behind long-prompt admissions, with and without
chunked prefill — plus the PR 5 replica fabric: a skewed workload (one
hot replica wedged on long RUNNING sequences, one cold) comparing
queue-only stealing (the cold replica can only pick up sequences the hot
one preempt-thrashes back to its queue, paying a chunked recompute
prefill per move) against live KV migration (running sequences ship
their written blocks at the first balance pass). Makespan in supersteps
is the deterministic headline metric for that pair — plus the PR 6
observability contract: the same fori_loop workload driven tracer-off
vs tracer-on (``serve_obs_overhead``: the disabled path is one
attribute check, the on-path must stay within a few percent and add
ZERO host syncs), registry-derived TTFT quantiles printed beside the
numpy ones on the TTFT rows, and the live-migration arm run under a
real ``Tracer`` whose validated Chrome trace JSON is written to
``BENCH_serve_trace.json`` (uploaded by CI next to the bench JSON).

PR 10 adds the predictive-balancing pair on a wedge+backlog workload:
short requests queued behind a hot replica's wedged slots, reactive
stealing vs cost-modeled diffusion (``serve_skew_predictive``, TTFT in
deterministic supersteps) plus the reactive-parity row
(``serve_skew_parity``: cost model attached, predictor off, decision
log byte-identical to plain reactive — the §16 contract).

Steady-state measurement: all slots admitted and kernels compiled before
the timer starts, so the numbers isolate the engine decode loop itself.
The model is a deliberately tiny 1-layer config — on CPU the per-token
*dispatch + host-sync* overhead is the quantity the fast path removes, and
a small model keeps it from being buried under compute that a TPU would
finish orders of magnitude faster.
"""
import dataclasses
import time

import jax
import numpy as np

from repro.configs import ARCHS
from repro.models import init_lm
from repro.obs import (FlightRecorder, Tracer, quantiles_from_values,
                       validate_chrome_trace)
from repro.serve.engine import Engine, GLBReplicaBalancer, Request

STEPS_PER_SYNC = 16
MAX_NEW = 96
MAX_SEQ = 128
SLOTS = 4
PAGED_BS = 8                      # pool block size (tokens)
SHORT_MAX_NEW = 16                # packing workload: short requests

# shared-prefix workload: a long system prompt every request begins with
SYS_PROMPT = [(7 * k + 3) % 250 + 1 for k in range(40)]
PREFIX_PAD = 64                   # prompt bucket for the prefix workload
N_PREFIX_REQS = 16
# TTFT workload: short requests arriving behind long-prompt admissions
TTFT_LONG_PROMPT = [(5 * k + 2) % 250 + 1 for k in range(120)]
TTFT_CHUNK = 16
# skewed-workload fabric: one hot replica wedged on long RUNNING
# sequences (queue empty, slots saturated), one cold — the scenario
# queue-only stealing cannot fix and live KV migration can
SKEW_REPLICAS = 2
SKEW_SLOTS = 4
SKEW_MAX_NEW = 110
SKEW_BLOCKS = 36        # fits 2 full seqs + lookahead comfortably, NOT 4:
                        # the queue-only arm must preempt-thrash instead
SKEW_CHUNK = 16         # chunked prefill makes a recompute resume COST
                        # supersteps — the work live migration avoids
TRACE_PATH = "BENCH_serve_trace.json"   # Chrome trace artifact (CI upload)
# predictive-vs-reactive arm (DESIGN.md §16): the same wedged fabric
# plus a backlog of short requests queued behind the wedge. Reactive
# stealing only moves the backlog when the cold replica starves;
# predictive diffusion moves it as soon as predicted block-seconds are
# imbalanced. TTFT is measured in SUPERSTEPS (first-token superstep per
# short request), so the headline comparison is deterministic and gates
# hard; the parity arm re-runs the reactive scenario with the cost
# model ATTACHED but predictive OFF and must reproduce the reactive
# decision log byte-for-byte.
PRED_LONG_MAX_NEW = 64
PRED_SHORT_MAX_NEW = 8
PRED_SHORTS = 4
PRED_TRACE_PATH = "BENCH_serve_predictive_trace.json"
# crash-recovery chaos arm (DESIGN.md §15): a 3-replica fabric loses one
# replica mid-flight; the deterministic acceptance metrics are zero lost
# requests, greedy-token-identical outputs vs an identical clean fabric,
# and termination (no wedge)
CHAOS_REPLICAS = 3
CHAOS_REQS = 6
CHAOS_MAX_NEW = 24
CHAOS_CRASH_AT = 1
FLIGHT_CAPACITY = 32    # below the run's event count: the flight row
                        # must exercise ring WRAPAROUND, not ample
                        # capacity, and still dump a valid trace
                        # (asserted — the steady-state workload emits
                        # ~60 ring events)


def _bench_cfg():
    return dataclasses.replace(
        ARCHS["tinyllama-1.1b"].smoke(), name="bench-serve-tiny",
        n_layers=1, d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
        d_ff=64, vocab=256, scan_layers=False,
    )


def _drive(engine, step_fn):
    for r in range(engine.max_slots):
        engine.submit(Request(rid=r, prompt=[3, r + 1, 4], max_new=MAX_NEW))
    step_fn()  # admits every slot + compiles prefill/decode
    toks0, syncs0 = engine.tokens_out, engine.host_syncs
    t0 = time.time()
    while engine.load > 0:
        step_fn()
    dt = time.time() - t0
    toks = engine.tokens_out - toks0
    return toks / dt, (engine.host_syncs - syncs0) / max(toks, 1)


def _best_of(make_engine, drive, repeats=2):
    """Best tokens/s over fresh runs — engine-vs-engine ratios on a noisy
    shared CPU need the envelope, not one sample."""
    best = None
    for _ in range(repeats):
        out = drive(make_engine())
        if best is None or out[0] > best[0]:
            best = out
    return best


def _drive_packing(engine, n_reqs):
    """Flood with short requests; measure steady throughput and the peak
    number of concurrently-running sequences."""
    for r in range(n_reqs):
        engine.submit(Request(rid=r, prompt=[3, r % 250 + 1, 4],
                              max_new=SHORT_MAX_NEW))
    engine.step()  # compile + first admissions
    toks0 = engine.tokens_out
    t0 = time.time()
    while engine.load > 0:
        engine.step()
    dt = time.time() - t0
    return (engine.tokens_out - toks0) / dt, engine.peak_running


def _drive_prefix(engine, n_reqs):
    """Shared-system-prompt workload: every request = SYS_PROMPT + a short
    unique tail. One warm-up request compiles the miss and hit paths and
    seeds the cache (the steady-state a shared system prompt lives in),
    then counters reset and the timed wave runs. Returns
    (tokens/s, prefill tokens saved fraction)."""
    for wid in (10_000, 10_001):    # first = miss path, second = hit path
        engine.submit(Request(rid=wid, prompt=SYS_PROMPT + [wid % 250, 5, 7],
                              max_new=2))
        while engine.load > 0:
            engine.step()
    if engine.prefix_cache is not None:
        c = engine.prefix_cache
        c.hits = c.misses = c.tokens_reused = 0
    reqs = [Request(rid=r, prompt=SYS_PROMPT + [r % 250 + 1, 5, 7],
                    max_new=SHORT_MAX_NEW) for r in range(n_reqs)]
    for r in reqs:
        engine.submit(r)
    toks0 = engine.tokens_out
    t0 = time.time()
    while engine.load > 0:
        engine.step()
    dt = time.time() - t0
    total_prefix = sum(min(len(r.prompt), engine.pad_len) for r in reqs)
    saved = (engine.prefix_cache.tokens_reused / total_prefix
             if engine.prefix_cache is not None else 0.0)
    return (engine.tokens_out - toks0) / dt, saved


def _drive_ttft(engine):
    """Staggered arrivals: a stream of short requests with long-prompt
    requests landing mid-stream. TTFT = wall-clock from submit to first
    output token, reported for the SHORT requests (the ones a monolithic
    long prefill starves — the long request itself legitimately pays for
    its own chunking). max_prefill_tokens is the largest single-step
    prefill the engine ever ran — THE quantity chunking bounds (on this
    deliberately tiny CPU model, per-step dispatch overhead swamps
    prefill compute, so the wall-clock columns mostly show that
    overhead; on a real model the per-step work bound is what keeps
    decode latency flat). Returns (short p50 ms, short p99 ms,
    max step ms, max prefill tokens in one step)."""
    schedule = []                   # (arrival_step, request, is_short)
    rid = 0
    for s in range(24):
        if s % 8 == 3:
            schedule.append((s, Request(
                rid=rid, prompt=list(TTFT_LONG_PROMPT), max_new=4), False))
            rid += 1
        schedule.append((s, Request(
            rid=rid, prompt=[3, rid % 250 + 1, 4], max_new=4), True))
        rid += 1
    # Warm-up outside the timer: same prompt shapes as the schedule, so
    # every prefill/chunk/decode trace is compiled before TTFT is measured.
    for req in (Request(rid=10_000, prompt=list(TTFT_LONG_PROMPT),
                        max_new=2),
                Request(rid=10_001, prompt=[3, 5, 4], max_new=2)):
        engine.submit(req)
    while engine.load > 0:
        engine.step()
    per_step_prefill = {}
    orig_chunk = engine._run_prefill_chunk

    def spy(slot, req, start, end, last):
        per_step_prefill[engine.steps] = (
            per_step_prefill.get(engine.steps, 0) + (end - start)
        )
        return orig_chunk(slot, req, start, end, last)

    engine._run_prefill_chunk = spy
    submit_t, first_t = {}, {}
    pending = list(schedule)
    step, max_step = 0, 0.0
    while pending or engine.load > 0:
        while pending and pending[0][0] <= step:
            _, req, _ = pending.pop(0)
            submit_t[req.rid] = time.time()
            engine.submit(req)
        t0 = time.time()
        engine.step()
        max_step = max(max_step, time.time() - t0)
        now = time.time()
        for _, req, _ in schedule:
            if req.rid not in first_t and req.out:
                first_t[req.rid] = now
        step += 1
        if step > 5000:
            break
    shorts = [
        1e3 * (first_t[req.rid] - submit_t[req.rid])
        for _, req, is_short in schedule
        if is_short and req.rid in first_t
    ]
    # Same samples through the metrics registry's fixed-bucket histogram:
    # the registry quantiles must agree with numpy's to within a bucket,
    # proving the Prometheus/merged view reports the numbers the bench does.
    reg_p50, reg_p99 = quantiles_from_values(shorts, (0.5, 0.99))
    return (float(np.percentile(shorts, 50)),
            float(np.percentile(shorts, 99)), 1e3 * max_step,
            max(per_step_prefill.values(), default=0), reg_p50, reg_p99)


def _mk_skew_engines(cfg, params, tracer=None):
    """One fabric: identical paged replicas whose pool fits ~2 full-length
    sequences with lookahead, not 4. pad_len == max_seq keeps every
    recompute prefill on ONE trace so wall-clock compares engines, not
    retraces."""
    return [
        Engine(cfg, params, max_slots=SKEW_SLOTS, max_seq=MAX_SEQ,
               pad_len=MAX_SEQ, steps_per_sync=STEPS_PER_SYNC, paged=True,
               block_size=PAGED_BS, num_blocks=SKEW_BLOCKS,
               prefill_chunk=SKEW_CHUNK,
               token_budget=SKEW_SLOTS * STEPS_PER_SYNC,
               tracer=tracer, replica_id=i)
        for i in range(SKEW_REPLICAS)
    ]


def _drive_skew(engines, migrate, rid0=0, tracer=None):
    """All requests land on replica 0 and are admitted there BEFORE the
    balancer runs — the wedged state: queue empty, every slot busy on a
    long sequence, N-1 cold replicas idle. Queue-only stealing can only
    move work after watermark preemption kicks a sequence back to the
    queue (losing its written KV to a recompute on the thief); live
    migration sheds running sequences with their KV intact at the first
    balance pass. Returns (makespan_s, supersteps, preemptions,
    migrations)."""
    bal = GLBReplicaBalancer(engines, migrate=migrate, tracer=tracer)
    reqs = [Request(rid=rid0 + r, prompt=[3, r + 1, 4],
                    max_new=SKEW_MAX_NEW) for r in range(SKEW_SLOTS)]
    for r in reqs:
        bal.submit(r, rr=0)
    engines[0].step()           # wedge: hot replica admits every slot
    p0 = sum(e.sched.preemptions for e in engines)
    t0 = time.time()
    bal.run(max_steps=2000)
    dt = time.time() - t0
    assert all(r.done for r in reqs)
    preempts = sum(e.sched.preemptions for e in engines) - p0
    return dt, bal.supersteps, preempts, bal.migrations


def _skew_arm(cfg, params, migrate, tracer=None):
    """Warm run on fresh engines (compiles every trace the arm hits),
    then the timed run REUSES the drained engines so both arms measure
    steady-state scheduling, not per-engine jit closures compiling.
    ``tracer`` records BOTH runs (the warm wave reads as a second
    request batch in the artifact); scheduling is deterministic so the
    gated superstep/preemption counts are tracer-independent."""
    engines = _mk_skew_engines(cfg, params, tracer=tracer)
    _drive_skew(engines, migrate, rid0=10_000, tracer=tracer)
    return _drive_skew(engines, migrate, rid0=0, tracer=tracer)


def _drive_skew_pred(engines, bal, rid0=0):
    """Wedge + backlog: PRED long requests admitted into every replica-0
    slot, then short requests queued behind them, cold replica idle.
    Drives the fabric superstep-by-superstep recording the superstep at
    which each short request produced its first token — TTFT in
    SUPERSTEPS, deterministic under greedy decode + deterministic
    matching. Returns (wall_s, supersteps, preemptions, short TTFT p99
    in supersteps, decision log)."""
    longs = [Request(rid=rid0 + r, prompt=[3, r + 1, 4],
                     max_new=PRED_LONG_MAX_NEW, tenant="long")
             for r in range(SKEW_SLOTS)]
    for r in longs:
        bal.submit(r, rr=0)
    engines[0].step()           # wedge: hot replica admits every slot
    shorts = [Request(rid=rid0 + 100 + r, prompt=[5, r + 1, 6],
                      max_new=PRED_SHORT_MAX_NEW, tenant="short")
              for r in range(PRED_SHORTS)]
    for r in shorts:
        bal.submit(r, rr=0)
    p0 = sum(e.sched.preemptions for e in engines)
    first = {}
    t0 = time.time()
    for _ in range(2000):
        if bal.balance():
            break
        for e in engines:
            e.step()
        bal.supersteps += 1
        for r in shorts:
            if r.rid not in first and r.out:
                first[r.rid] = bal.supersteps
    dt = time.time() - t0
    assert all(r.done for r in longs + shorts)
    preempts = sum(e.sched.preemptions for e in engines) - p0
    ttfts = [first[r.rid] for r in shorts]
    return (dt, bal.supersteps, preempts,
            float(np.percentile(ttfts, 99)), list(bal.decisions))


def _pred_arm(cfg, params, cost_model=None, predictive=False,
              tracer=None):
    """Warm run compiles every trace (and, with a cost model, seeds the
    per-tenant decode histograms — the steady-state an online predictor
    lives in), then the timed run reuses the drained engines under a
    FRESH balancer so its decision log covers exactly one scenario."""
    engines = _mk_skew_engines(cfg, params, tracer=tracer)

    def mk_bal():
        return GLBReplicaBalancer(engines, migrate=True, tracer=tracer,
                                  cost_model=cost_model,
                                  predictive=predictive)

    _drive_skew_pred(engines, mk_bal(), rid0=20_000)
    bal = mk_bal()
    return _drive_skew_pred(engines, bal, rid0=0), bal


def _chaos_arm(cfg, params, faults=None):
    """One fabric run for the crash-recovery row: CHAOS_REQS requests
    round-robined over CHAOS_REPLICAS paged replicas; with ``faults``,
    replica 0 crashes at superstep CHAOS_CRASH_AT while its work is
    still in flight. Scheduling and recovery are deterministic (greedy
    decode, heartbeat window on the superstep clock), so everything but
    wall-clock gates hard."""
    engines = [
        Engine(cfg, params, max_slots=2, max_seq=MAX_SEQ, pad_len=8,
               steps_per_sync=STEPS_PER_SYNC, paged=True,
               block_size=PAGED_BS, num_blocks=32, replica_id=i)
        for i in range(CHAOS_REPLICAS)
    ]
    bal = GLBReplicaBalancer(engines, migrate=True, faults=faults)
    reqs = [Request(rid=r, prompt=[3, r + 1, 4], max_new=CHAOS_MAX_NEW)
            for r in range(CHAOS_REQS)]
    for r in reqs:
        bal.submit(r)
    t0 = time.time()
    status = bal.run(max_steps=2000)
    dt = time.time() - t0
    lost = sum(1 for r in reqs if not r.done)
    return dt, status, bal, lost, [list(r.out) for r in reqs]


def run():
    cfg = _bench_cfg()
    params = init_lm(jax.random.key(0), cfg)

    tps_old, spt_old = _best_of(
        lambda: Engine(cfg, params, max_slots=SLOTS, max_seq=MAX_SEQ,
                       pad_len=8, steps_per_sync=1),
        lambda e: _drive(e, e.step_legacy),
    )

    tps_new, spt_new = _best_of(
        lambda: Engine(cfg, params, max_slots=SLOTS, max_seq=MAX_SEQ,
                       pad_len=8, steps_per_sync=STEPS_PER_SYNC),
        lambda e: _drive(e, e.step),
    )

    # Observability overhead: the identical fori_loop workload with a
    # LIVE Tracer (engine-step spans, load/pool counters, request
    # lifecycle events, metrics observations). tracer-off IS tps_new —
    # the disabled path is one attribute check on NULL_TRACER. The
    # deterministic invariant is syncs/token: tracing must add ZERO
    # host syncs (events are host-side dict appends, never device
    # drains); tokens/s overhead gates advisorily in compare.py.
    tps_on, spt_on = _best_of(
        lambda: Engine(cfg, params, max_slots=SLOTS, max_seq=MAX_SEQ,
                       pad_len=8, steps_per_sync=STEPS_PER_SYNC,
                       tracer=Tracer()),
        lambda e: _drive(e, e.step),
    )
    obs_overhead = 100.0 * (1.0 - tps_on / max(tps_new, 1e-9))

    # Flight-recorder overhead: the same workload tracing into a ring
    # bounded FAR below the run's event count (forced wraparound), i.e.
    # always-on tracing at fixed memory. Deterministic invariants:
    # syncs/token unchanged (HARD gate) and the wrapped ring still
    # dumps a validator-clean trace (dump_valid, HARD gate).
    flights = []

    def _mk_flight():
        fr = FlightRecorder(capacity=FLIGHT_CAPACITY)
        flights.append(fr)
        return Engine(cfg, params, max_slots=SLOTS, max_seq=MAX_SEQ,
                      pad_len=8, steps_per_sync=STEPS_PER_SYNC,
                      tracer=fr)

    tps_fl, spt_fl = _best_of(_mk_flight, lambda e: _drive(e, e.step))
    flight = flights[-1]
    flight_valid = int(validate_chrome_trace(flight.dump()) == [])
    assert len(flight.events) <= FLIGHT_CAPACITY
    assert flight.dropped > 0, (
        "flight bench must wrap the ring; raise the workload or shrink "
        f"FLIGHT_CAPACITY (events={len(flight.events)})"
    )

    # Paged pool, same workload and same KV rows as the contiguous engine:
    # tokens/s should track the contiguous fast path (the pool adds a
    # block-table walk, not extra attention work).
    rows = SLOTS * MAX_SEQ
    tps_pg, spt_pg = _best_of(
        lambda: Engine(cfg, params, max_slots=SLOTS, max_seq=MAX_SEQ,
                       pad_len=8, steps_per_sync=STEPS_PER_SYNC,
                       paged=True, block_size=PAGED_BS,
                       num_blocks=rows // PAGED_BS),
        lambda e: _drive(e, e.step),
    )

    # Packing at fixed HBM: the contiguous engine reserves max_seq rows
    # per slot, so `rows` of cache memory cap it at SLOTS concurrent
    # sequences; the paged engine packs by actual length.
    n_reqs = 3 * rows // (PAGED_BS + SHORT_MAX_NEW)
    tps_pc, conc_c = _best_of(
        lambda: Engine(cfg, params, max_slots=SLOTS, max_seq=MAX_SEQ,
                       pad_len=8, steps_per_sync=STEPS_PER_SYNC),
        lambda e: _drive_packing(e, n_reqs),
    )
    tps_pp, conc_p = _best_of(
        lambda: Engine(cfg, params, max_slots=rows // PAGED_BS,
                       max_seq=MAX_SEQ, pad_len=8,
                       steps_per_sync=STEPS_PER_SYNC, paged=True,
                       block_size=PAGED_BS, num_blocks=rows // PAGED_BS),
        lambda e: _drive_packing(e, n_reqs),
    )

    # Shared-system-prompt workload: identical engine/pool, prefix cache
    # off vs on. "saved" = fraction of prefill positions served from
    # cached blocks instead of recomputed.
    pool_kw = dict(max_slots=SLOTS, max_seq=MAX_SEQ, pad_len=PREFIX_PAD,
                   steps_per_sync=STEPS_PER_SYNC, paged=True,
                   block_size=PAGED_BS, num_blocks=rows // PAGED_BS)
    tps_nc, _ = _best_of(lambda: Engine(cfg, params, **pool_kw),
                         lambda e: _drive_prefix(e, N_PREFIX_REQS))
    (tps_cache, saved) = _best_of(
        lambda: Engine(cfg, params, prefix_cache=True, **pool_kw),
        lambda e: _drive_prefix(e, N_PREFIX_REQS),
    )

    # TTFT with and without chunked prefill. Both arms run the chunk-mode
    # admission path with pad_len = MAX_SEQ (the 120-token prompt must
    # not be bucket-truncated). The baseline admits each prompt as ONE
    # monolithic chunk with no token budget (pre-chunking behavior:
    # unbounded per-step prefill); the chunked arm bounds every step by
    # the shared token budget.
    ttft_kw = dict(pool_kw, pad_len=MAX_SEQ)
    p50_nc_t, p99_nc_t, step_nc, pf_nc, rp50_nc, rp99_nc = _drive_ttft(
        Engine(cfg, params, prefill_chunk=MAX_SEQ, **ttft_kw)
    )
    p50_ck, p99_ck, step_ck, pf_ck, rp50_ck, rp99_ck = _drive_ttft(
        Engine(cfg, params, prefill_chunk=TTFT_CHUNK,
               token_budget=SLOTS * STEPS_PER_SYNC, **ttft_kw)
    )

    # Skewed fabric: queue-only stealing vs live KV migration. Makespan
    # in SUPERSTEPS is the deterministic acceptance metric (greedy
    # decode + deterministic matching); wall-clock rides along.
    dt_q, steps_q, pre_q, _ = _skew_arm(cfg, params, migrate=False)
    # The live-migration arm doubles as the trace artifact: the whole
    # fabric run (admissions, preemptions, steal/migration timeline)
    # lands in BENCH_serve_trace.json for the CI upload. Scheduling is
    # deterministic, so the gated superstep counts are unaffected; only
    # the advisory wall-clock column carries the (small) tracer cost.
    tracer = Tracer()
    dt_m, steps_m, pre_m, migs = _skew_arm(cfg, params, migrate=True,
                                           tracer=tracer)
    tracer.write(TRACE_PATH)
    problems = validate_chrome_trace(tracer.to_chrome())
    assert not problems, problems

    # Predictive vs reactive on the wedge+backlog scenario. Everything
    # gated is deterministic (supersteps, preemptions, first-token
    # supersteps, decision-log identity), so the ISSUE contract asserts
    # inline AND gates hard in compare.py: predictive must terminate in
    # no more supersteps with no more preemptions and no worse short
    # TTFT, and the parity arm (cost model attached, predictor OFF)
    # must reproduce the reactive decision log exactly.
    from repro.serve.cost import CostModel
    (dt_r, steps_r, pre_r, ttft_r, dec_r), _ = _pred_arm(cfg, params)
    (dt_par, steps_par, _, _, dec_par), _ = _pred_arm(
        cfg, params, cost_model=CostModel())
    ptracer = Tracer()
    (dt_p, steps_p, pre_p, ttft_p, _), bal_p = _pred_arm(
        cfg, params, cost_model=CostModel(), predictive=True,
        tracer=ptracer)
    ptracer.write(PRED_TRACE_PATH)
    assert not validate_chrome_trace(ptracer.to_chrome())
    parity = int(dec_par == dec_r and steps_par == steps_r)
    assert parity == 1, (
        f"reactive parity broken: {dec_par} != {dec_r} "
        f"or {steps_par} != {steps_r}"
    )
    assert steps_p <= steps_r, (steps_p, steps_r)
    assert pre_p <= pre_r, (pre_p, pre_r)
    assert ttft_p <= ttft_r, (ttft_p, ttft_r)
    cost_snap = bal_p.cost_model.snapshot()

    # Crash recovery: identical fabric clean vs one replica crashed
    # mid-flight. The crashed arm must terminate with zero lost
    # requests and greedy-token-identical outputs (HARD gates); the
    # superstep makespan quantifies the recovery detour.
    from repro.serve.faults import FaultInjector
    _chaos_arm(cfg, params)                       # warm/compile
    dt_cl, st_cl, bal_cl, lost_cl, outs_cl = _chaos_arm(cfg, params)
    assert st_cl == "terminated" and lost_cl == 0
    dt_cr, st_cr, bal_cr, lost_cr, outs_cr = _chaos_arm(
        cfg, params,
        faults=FaultInjector().crash(0, at=CHAOS_CRASH_AT),
    )
    assert st_cr == "terminated", "crashed fabric wedged"
    readmitted = bal_cr.readmitted_queued + bal_cr.readmitted_running
    greedy_identical = int(outs_cr == outs_cl)

    # syncs per decoded *position* is the architectural constant: the
    # legacy loop drains every position (1.0), the fori_loop engine drains
    # once per steps_per_sync positions.
    return [
        ("serve_legacy_loop", 1e6 / max(tps_old, 1e-9),
         f"tok_s={tps_old:.1f};syncs_per_tok={spt_old:.3f};"
         f"syncs_per_pos=1.000"),
        ("serve_fori_loop", 1e6 / max(tps_new, 1e-9),
         f"tok_s={tps_new:.1f};syncs_per_tok={spt_new:.3f};"
         f"syncs_per_pos={1.0 / STEPS_PER_SYNC:.3f};"
         f"speedup={tps_new / max(tps_old, 1e-9):.2f}x"),
        ("serve_obs_overhead", 1e6 / max(tps_on, 1e-9),
         f"tok_s_on={tps_on:.1f};tok_s_off={tps_new:.1f};"
         f"overhead_pct={obs_overhead:.1f};"
         f"syncs_per_tok_on={spt_on:.3f};"
         f"syncs_per_tok_off={spt_new:.3f}"),
        ("serve_flight_overhead", 1e6 / max(tps_fl, 1e-9),
         f"tok_s={tps_fl:.1f};"
         f"vs_untraced={tps_fl / max(tps_new, 1e-9):.2f}x;"
         f"syncs_per_tok={spt_fl:.3f};"
         f"ring_capacity={FLIGHT_CAPACITY};"
         f"ring_events={len(flight.events)};"
         f"dropped_events={flight.dropped};"
         f"dump_valid={flight_valid}"),
        ("serve_paged_loop", 1e6 / max(tps_pg, 1e-9),
         f"tok_s={tps_pg:.1f};syncs_per_tok={spt_pg:.3f};"
         f"vs_contiguous={tps_pg / max(tps_new, 1e-9):.2f}x;"
         f"block_size={PAGED_BS}"),
        ("serve_packing_contiguous", 1e6 / max(tps_pc, 1e-9),
         f"tok_s={tps_pc:.1f};max_concurrent={conc_c};"
         f"hbm_rows={rows}"),
        ("serve_packing_paged", 1e6 / max(tps_pp, 1e-9),
         f"tok_s={tps_pp:.1f};max_concurrent={conc_p};"
         f"hbm_rows={rows};concurrency_gain="
         f"{conc_p / max(conc_c, 1):.1f}x"),
        ("serve_prefix_cache", 1e6 / max(tps_cache, 1e-9),
         f"tok_s={tps_cache:.1f};vs_no_cache="
         f"{tps_cache / max(tps_nc, 1e-9):.2f}x;"
         f"prefill_tokens_saved={saved:.0%};"
         f"sys_prompt_len={len(SYS_PROMPT)};reqs={N_PREFIX_REQS}"),
        ("serve_ttft_nochunk", 1e3 * p50_nc_t,
         f"short_ttft_p50_ms={p50_nc_t:.1f};"
         f"short_ttft_p99_ms={p99_nc_t:.1f};"
         f"reg_p50_ms={rp50_nc:.1f};reg_p99_ms={rp99_nc:.1f};"
         f"max_step_ms={step_nc:.1f};"
         f"max_prefill_tokens_per_step={pf_nc};"
         f"long_prompt={len(TTFT_LONG_PROMPT)}"),
        ("serve_ttft_chunked", 1e3 * p50_ck,
         f"short_ttft_p50_ms={p50_ck:.1f};short_ttft_p99_ms={p99_ck:.1f};"
         f"reg_p50_ms={rp50_ck:.1f};reg_p99_ms={rp99_ck:.1f};"
         f"max_step_ms={step_ck:.1f};"
         f"max_prefill_tokens_per_step={pf_ck};chunk={TTFT_CHUNK};"
         f"p99_vs_nochunk={p99_ck / max(p99_nc_t, 1e-9):.2f}x;"
         f"max_step_vs_nochunk={step_ck / max(step_nc, 1e-9):.2f}x"),
        ("serve_skew_queue_steal", 1e6 * dt_q,
         f"makespan_s={dt_q:.2f};makespan_steps={steps_q};"
         f"preemptions={pre_q};replicas={SKEW_REPLICAS};"
         f"slots={SKEW_SLOTS};pool_blocks={SKEW_BLOCKS}"),
        ("serve_skew_live_migration", 1e6 * dt_m,
         f"makespan_s={dt_m:.2f};makespan_steps={steps_m};"
         f"preemptions={pre_m};migrations={migs};"
         f"steps_vs_queue_steal={steps_m / max(steps_q, 1):.2f}x;"
         f"wall_vs_queue_steal={dt_m / max(dt_q, 1e-9):.2f}x;"
         f"trace_events={len(tracer.events)};trace={TRACE_PATH}"),
        ("serve_skew_predictive", 1e6 * dt_p,
         f"makespan_s={dt_p:.2f};makespan_steps={steps_p};"
         f"reactive_steps={steps_r};"
         f"steps_vs_reactive={steps_p / max(steps_r, 1):.2f}x;"
         f"preemptions={pre_p};reactive_preemptions={pre_r};"
         f"ttft_p99_steps={ttft_p:.0f};"
         f"reactive_ttft_p99_steps={ttft_r:.0f};"
         f"diffusion_moves={bal_p.diffusion_moves};"
         f"predictions={cost_snap['cost_predictions']};"
         f"mean_abs_err_tokens={cost_snap['cost_mean_abs_err_tokens']:.1f};"
         f"wall_vs_reactive={dt_p / max(dt_r, 1e-9):.2f}x;"
         f"trace_events={len(ptracer.events)};trace={PRED_TRACE_PATH}"),
        ("serve_skew_parity", 1e6 * dt_par,
         f"decisions_identical={parity};decisions={len(dec_par)};"
         f"makespan_steps={steps_par};reactive_steps={steps_r}"),
        ("serve_crash_recovery", 1e6 * dt_cr,
         f"makespan_s={dt_cr:.2f};makespan_steps={bal_cr.supersteps};"
         f"clean_steps={bal_cl.supersteps};"
         f"requests_lost={lost_cr};readmitted={readmitted};"
         f"replicas_dead={bal_cr.replicas_dead};"
         f"terminated={int(st_cr == 'terminated')};"
         f"greedy_identical={greedy_identical};"
         f"steps_vs_clean="
         f"{bal_cr.supersteps / max(bal_cl.supersteps, 1):.2f}x;"
         f"wall_vs_clean={dt_cr / max(dt_cl, 1e-9):.2f}x;"
         f"crash_at={CHAOS_CRASH_AT};replicas={CHAOS_REPLICAS}"),
    ]


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
