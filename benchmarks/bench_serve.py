"""Serving engine throughput: tokens/s and host syncs per token for the
legacy per-token decode loop vs the jitted multi-step ``lax.fori_loop``
engine (on-device sampling, one host drain per N positions).

Steady-state measurement: all slots admitted and kernels compiled before
the timer starts, so the numbers isolate the engine decode loop itself.
The model is a deliberately tiny 1-layer config — on CPU the per-token
*dispatch + host-sync* overhead is the quantity the fast path removes, and
a small model keeps it from being buried under compute that a TPU would
finish orders of magnitude faster.
"""
import dataclasses
import time

import jax

from repro.configs import ARCHS
from repro.models import init_lm
from repro.serve.engine import Engine, Request

STEPS_PER_SYNC = 16
MAX_NEW = 96


def _bench_cfg():
    return dataclasses.replace(
        ARCHS["tinyllama-1.1b"].smoke(), name="bench-serve-tiny",
        n_layers=1, d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
        d_ff=64, vocab=256, scan_layers=False,
    )


def _drive(engine, step_fn):
    for r in range(engine.max_slots):
        engine.submit(Request(rid=r, prompt=[3, r + 1, 4], max_new=MAX_NEW))
    step_fn()  # admits every slot + compiles prefill/decode
    toks0, syncs0 = engine.tokens_out, engine.host_syncs
    t0 = time.time()
    while engine.load > 0:
        step_fn()
    dt = time.time() - t0
    toks = engine.tokens_out - toks0
    return toks / dt, (engine.host_syncs - syncs0) / max(toks, 1)


def run():
    cfg = _bench_cfg()
    params = init_lm(jax.random.key(0), cfg)

    old = Engine(cfg, params, max_slots=4, max_seq=128, pad_len=8,
                 steps_per_sync=1)
    tps_old, spt_old = _drive(old, old.step_legacy)

    new = Engine(cfg, params, max_slots=4, max_seq=128, pad_len=8,
                 steps_per_sync=STEPS_PER_SYNC)
    tps_new, spt_new = _drive(new, new.step)

    # syncs per decoded *position* is the architectural constant: the
    # legacy loop drains every position (1.0), the fori_loop engine drains
    # once per steps_per_sync positions.
    return [
        ("serve_legacy_loop", 1e6 / max(tps_old, 1e-9),
         f"tok_s={tps_old:.1f};syncs_per_tok={spt_old:.3f};"
         f"syncs_per_pos=1.000"),
        ("serve_fori_loop", 1e6 / max(tps_new, 1e-9),
         f"tok_s={tps_new:.1f};syncs_per_tok={spt_new:.3f};"
         f"syncs_per_pos={1.0 / STEPS_PER_SYNC:.3f};"
         f"speedup={tps_new / max(tps_old, 1e-9):.2f}x"),
    ]


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
