"""Serving engine throughput: tokens/s and host syncs per token for the
legacy per-token decode loop vs the jitted multi-step ``lax.fori_loop``
engine (on-device sampling, one host drain per N positions), plus the
paged KV pool vs contiguous slots — same-workload tokens/s and max
concurrent sequences at fixed cache memory (the paged packing win).

Steady-state measurement: all slots admitted and kernels compiled before
the timer starts, so the numbers isolate the engine decode loop itself.
The model is a deliberately tiny 1-layer config — on CPU the per-token
*dispatch + host-sync* overhead is the quantity the fast path removes, and
a small model keeps it from being buried under compute that a TPU would
finish orders of magnitude faster.
"""
import dataclasses
import time

import jax

from repro.configs import ARCHS
from repro.models import init_lm
from repro.serve.engine import Engine, Request

STEPS_PER_SYNC = 16
MAX_NEW = 96
MAX_SEQ = 128
SLOTS = 4
PAGED_BS = 8                      # pool block size (tokens)
SHORT_MAX_NEW = 16                # packing workload: short requests


def _bench_cfg():
    return dataclasses.replace(
        ARCHS["tinyllama-1.1b"].smoke(), name="bench-serve-tiny",
        n_layers=1, d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
        d_ff=64, vocab=256, scan_layers=False,
    )


def _drive(engine, step_fn):
    for r in range(engine.max_slots):
        engine.submit(Request(rid=r, prompt=[3, r + 1, 4], max_new=MAX_NEW))
    step_fn()  # admits every slot + compiles prefill/decode
    toks0, syncs0 = engine.tokens_out, engine.host_syncs
    t0 = time.time()
    while engine.load > 0:
        step_fn()
    dt = time.time() - t0
    toks = engine.tokens_out - toks0
    return toks / dt, (engine.host_syncs - syncs0) / max(toks, 1)


def _best_of(make_engine, drive, repeats=2):
    """Best tokens/s over fresh runs — engine-vs-engine ratios on a noisy
    shared CPU need the envelope, not one sample."""
    best = None
    for _ in range(repeats):
        out = drive(make_engine())
        if best is None or out[0] > best[0]:
            best = out
    return best


def _drive_packing(engine, n_reqs):
    """Flood with short requests; measure steady throughput and the peak
    number of concurrently-running sequences."""
    for r in range(n_reqs):
        engine.submit(Request(rid=r, prompt=[3, r % 250 + 1, 4],
                              max_new=SHORT_MAX_NEW))
    engine.step()  # compile + first admissions
    toks0 = engine.tokens_out
    t0 = time.time()
    while engine.load > 0:
        engine.step()
    dt = time.time() - t0
    return (engine.tokens_out - toks0) / dt, engine.peak_running


def run():
    cfg = _bench_cfg()
    params = init_lm(jax.random.key(0), cfg)

    tps_old, spt_old = _best_of(
        lambda: Engine(cfg, params, max_slots=SLOTS, max_seq=MAX_SEQ,
                       pad_len=8, steps_per_sync=1),
        lambda e: _drive(e, e.step_legacy),
    )

    tps_new, spt_new = _best_of(
        lambda: Engine(cfg, params, max_slots=SLOTS, max_seq=MAX_SEQ,
                       pad_len=8, steps_per_sync=STEPS_PER_SYNC),
        lambda e: _drive(e, e.step),
    )

    # Paged pool, same workload and same KV rows as the contiguous engine:
    # tokens/s should track the contiguous fast path (the pool adds a
    # block-table walk, not extra attention work).
    rows = SLOTS * MAX_SEQ
    tps_pg, spt_pg = _best_of(
        lambda: Engine(cfg, params, max_slots=SLOTS, max_seq=MAX_SEQ,
                       pad_len=8, steps_per_sync=STEPS_PER_SYNC,
                       paged=True, block_size=PAGED_BS,
                       num_blocks=rows // PAGED_BS),
        lambda e: _drive(e, e.step),
    )

    # Packing at fixed HBM: the contiguous engine reserves max_seq rows
    # per slot, so `rows` of cache memory cap it at SLOTS concurrent
    # sequences; the paged engine packs by actual length.
    n_reqs = 3 * rows // (PAGED_BS + SHORT_MAX_NEW)
    tps_pc, conc_c = _best_of(
        lambda: Engine(cfg, params, max_slots=SLOTS, max_seq=MAX_SEQ,
                       pad_len=8, steps_per_sync=STEPS_PER_SYNC),
        lambda e: _drive_packing(e, n_reqs),
    )
    tps_pp, conc_p = _best_of(
        lambda: Engine(cfg, params, max_slots=rows // PAGED_BS,
                       max_seq=MAX_SEQ, pad_len=8,
                       steps_per_sync=STEPS_PER_SYNC, paged=True,
                       block_size=PAGED_BS, num_blocks=rows // PAGED_BS),
        lambda e: _drive_packing(e, n_reqs),
    )

    # syncs per decoded *position* is the architectural constant: the
    # legacy loop drains every position (1.0), the fori_loop engine drains
    # once per steps_per_sync positions.
    return [
        ("serve_legacy_loop", 1e6 / max(tps_old, 1e-9),
         f"tok_s={tps_old:.1f};syncs_per_tok={spt_old:.3f};"
         f"syncs_per_pos=1.000"),
        ("serve_fori_loop", 1e6 / max(tps_new, 1e-9),
         f"tok_s={tps_new:.1f};syncs_per_tok={spt_new:.3f};"
         f"syncs_per_pos={1.0 / STEPS_PER_SYNC:.3f};"
         f"speedup={tps_new / max(tps_old, 1e-9):.2f}x"),
        ("serve_paged_loop", 1e6 / max(tps_pg, 1e-9),
         f"tok_s={tps_pg:.1f};syncs_per_tok={spt_pg:.3f};"
         f"vs_contiguous={tps_pg / max(tps_new, 1e-9):.2f}x;"
         f"block_size={PAGED_BS}"),
        ("serve_packing_contiguous", 1e6 / max(tps_pc, 1e-9),
         f"tok_s={tps_pc:.1f};max_concurrent={conc_c};"
         f"hbm_rows={rows}"),
        ("serve_packing_paged", 1e6 / max(tps_pp, 1e-9),
         f"tok_s={tps_pp:.1f};max_concurrent={conc_p};"
         f"hbm_rows={rows};concurrency_gain="
         f"{conc_p / max(conc_c, 1):.1f}x"),
    ]


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
