"""Paper Figures 2/3/4: UTS throughput + efficiency vs place count.

The paper plots nodes/s (primary axis) and per-place efficiency (secondary
axis) on Power 775 / BG/Q / K. On one CPU core the honest analogues are:
  - wall nodes/s (for reference),
  - superstep efficiency = nodes / (supersteps * P * n): the fraction of
    available work slots actually used — this is what the paper's per-place
    efficiency measures (idle + steal overhead), and it is hardware-neutral.
Two lines: UTS-G (full lifeline algorithm) and UTS-R (random-only stealing,
the classic work-stealing baseline the lifeline paper improves on).
"""
import time

import numpy as np

from repro.core import GLBParams, run_sim
from repro.problems.uts import uts_oracle, uts_problem

PLACES = (1, 2, 4, 8, 16, 32)
DEPTH = 9


def run():
    rows = []
    oracle = uts_oracle(4.0, DEPTH, 19)
    for variant, params in (
        ("uts_g", GLBParams(n=256, w=2, steal_k=64)),
        ("uts_random_only", GLBParams(n=256, w=2, z=1, steal_k=64)),
    ):
        for P in PLACES:
            prob = uts_problem(4.0, DEPTH, 19)
            t0 = time.time()
            out = run_sim(prob, P, params, seed=0)
            dt = time.time() - t0
            assert int(out.result) == oracle, (variant, P)
            steps = int(out.supersteps)
            eff = oracle / (steps * P * params.n)
            proc = np.asarray(out.stats["processed"], np.float64)
            rows.append((
                f"{variant}_p{P}",
                dt / steps * 1e6,  # us per superstep
                f"eff={eff:.3f};nodes_s={oracle/dt:.0f};steps={steps};"
                f"work_std_over_mean={proc.std()/max(proc.mean(),1e-9):.3f}",
            ))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
