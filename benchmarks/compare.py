"""Bench regression gate: compare freshly-written ``BENCH_<suite>.json``
files against the baselines committed under ``benchmarks/baselines/`` and
fail (exit 1) when a tracked metric regresses beyond its tolerance.

Two classes of metric, because CI runners are noisy:

* **deterministic** — structural quantities a code change moves and noise
  cannot (supersteps, syncs/token, max prefill tokens per step, max
  concurrency, kernel error vs oracle). These gate tightly;
* **wall-clock** — tokens/s and µs/call on a shared CPU runner. These
  gate loosely AND advisorily: a breach lands in the step summary as a
  warning but does not fail the job, because committed baselines may
  come from a different machine class than the CI runner (a dropped
  row still hard-fails — disappearance is structural).

A tracked row missing from the fresh run fails the gate (a silently
dropped benchmark is itself a regression); a tracked row missing from
the baseline is reported as NEW and passes. The full diff is written as
a markdown table to ``--summary`` (the CI step summary) and stdout.

  PYTHONPATH=src python benchmarks/compare.py \
      --baseline-dir benchmarks/baselines [--current-dir .] \
      [--summary out.md] [suites...]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import re
import sys
from typing import Dict, Optional


@dataclasses.dataclass(frozen=True)
class Gate:
    suite: str
    row: str
    metric: str        # key inside `derived`, or "us_per_call"
    better: str        # "higher" | "lower"
    rel_tol: float     # allowed relative regression (0.10 = 10% worse ok)
    abs_tol: float = 0.0   # additionally allowed absolute slack
    note: str = ""
    hard: bool = True  # False: report the breach in the summary but do
                       # not fail the job — wall-clock metrics gate soft
                       # because committed baselines may come from a
                       # different machine class than the CI runner;
                       # deterministic metrics stay hard.


# ---------------------------------------------------------------- tracked
GATES = [
    # --- serve: deterministic structure -------------------------------
    Gate("serve", "serve_fori_loop", "syncs_per_tok", "lower", 0.01,
         note="host syncs per token is the fast path's invariant"),
    Gate("serve", "serve_packing_paged", "max_concurrent", "higher", 0.0,
         note="paged packing at fixed HBM"),
    Gate("serve", "serve_prefix_cache", "prefill_tokens_saved", "higher",
         0.0, abs_tol=5.0, note="shared-prefix reuse (% points)"),
    Gate("serve", "serve_ttft_chunked", "max_prefill_tokens_per_step",
         "lower", 0.0, abs_tol=2.0,
         note="THE bound chunked prefill exists to enforce"),
    Gate("serve", "serve_skew_live_migration", "makespan_steps", "lower",
         0.0, abs_tol=1.0,
         note="skewed-fabric makespan with live KV migration"),
    Gate("serve", "serve_skew_live_migration", "steps_vs_queue_steal",
         "lower", 0.0, abs_tol=0.15,
         note="live migration must keep beating queue-only stealing"),
    # rel_tol 0.5 of baseline 2 => floor 1: the intent is only "the
    # queue-only arm still preempts at all", not "as often as baseline"
    # (a benign scheduler improvement may preempt less).
    Gate("serve", "serve_skew_queue_steal", "preemptions", "higher", 0.5,
         note="the queue-only arm must still thrash (else the scenario "
              "no longer exercises the contrast)"),
    # Tracing must never add host syncs — events are host-side dict
    # appends. Deterministic, so it gates hard like the off-arm's.
    Gate("serve", "serve_obs_overhead", "syncs_per_tok_on", "lower", 0.01,
         note="a live tracer adds ZERO device drains"),
    # The bounded flight ring keeps both deterministic contracts: no
    # extra host syncs, and a WRAPPED ring still dumps a balanced,
    # validator-clean trace (dump_valid is 0/1).
    Gate("serve", "serve_flight_overhead", "syncs_per_tok", "lower", 0.01,
         note="a live flight recorder adds ZERO device drains"),
    Gate("serve", "serve_flight_overhead", "dump_valid", "higher", 0.0,
         note="wrapped ring must dump a validator-clean trace"),
    # Predictive balancing (DESIGN.md §16): supersteps, preemptions and
    # first-token supersteps are deterministic under greedy decode +
    # deterministic diffusion/matching, so the predictive-vs-reactive
    # contract gates hard at its committed values; the parity row is
    # THE regression tripwire for "predictor off == today's balancer".
    Gate("serve", "serve_skew_predictive", "steps_vs_reactive", "lower",
         0.0, abs_tol=0.05,
         note="predictive makespan must stay <= reactive supersteps"),
    Gate("serve", "serve_skew_predictive", "ttft_p99_steps", "lower",
         0.0, abs_tol=1.0,
         note="short-request TTFT p99 in supersteps, predictive arm"),
    Gate("serve", "serve_skew_predictive", "preemptions", "lower", 0.0,
         abs_tol=1.0, note="diffusion moves work BEFORE thrash"),
    Gate("serve", "serve_skew_predictive", "diffusion_moves", "higher",
         0.0, note="the predictive arm must actually diffuse (else the "
                   "scenario no longer exercises the cost model)"),
    Gate("serve", "serve_skew_parity", "decisions_identical", "higher",
         0.0, note="predictor off must reproduce the reactive decision "
                   "log byte-for-byte (0/1)"),
    # Crash recovery (DESIGN.md §15): deterministic fabric — greedy
    # decode + heartbeat window on the superstep clock — so the loss
    # and identity contracts gate hard at exactly their ideal values.
    Gate("serve", "serve_crash_recovery", "requests_lost", "lower", 0.0,
         note="a crashed replica's requests are re-admitted, never lost"),
    Gate("serve", "serve_crash_recovery", "terminated", "higher", 0.0,
         note="the crashed fabric must still terminate (0/1)"),
    Gate("serve", "serve_crash_recovery", "greedy_identical", "higher",
         0.0, note="re-admitted outputs token-identical to a clean run"),
    Gate("serve", "serve_crash_recovery", "readmitted", "higher", 0.0,
         note="the crash must actually cost recovery work (else the "
              "scenario no longer exercises the ledger)"),
    # --- serve: wall-clock, loose + advisory --------------------------
    Gate("serve", "serve_fori_loop", "tok_s", "higher", 0.60,
         note="decode throughput cliff detector", hard=False),
    Gate("serve", "serve_paged_loop", "tok_s", "higher", 0.60,
         hard=False),
    # Tracer-on overhead vs the committed baseline: warn past +5 points
    # (wall-clock on a shared runner, so advisory — the contract itself
    # lives in the tracer-off row and the syncs gate above).
    Gate("serve", "serve_obs_overhead", "overhead_pct", "lower", 0.0,
         abs_tol=5.0, note="tracer-on tokens/s cost (% points)",
         hard=False),
    Gate("serve", "serve_obs_overhead", "tok_s_off", "higher", 0.60,
         note="tracer-off throughput must track serve_fori_loop",
         hard=False),
    Gate("serve", "serve_flight_overhead", "tok_s", "higher", 0.60,
         note="ring-buffer tracing throughput cliff detector",
         hard=False),
    # --- kernels: oracle agreement is deterministic -------------------
    Gate("kernels", "attn_chunked_1k", "err", "lower", 0.0, abs_tol=1e-5,
         note="flash attention vs reference"),
    Gate("kernels", "flash_decode_interp", "err", "lower", 0.0,
         abs_tol=1e-5, note="decode kernel vs oracle"),
    # --- kernels: wall-clock, loose + advisory ------------------------
    Gate("kernels", "attn_chunked_1k", "us_per_call", "lower", 2.0,
         hard=False),
    Gate("kernels", "ssd_chunked_512", "us_per_call", "lower", 2.0,
         hard=False),
]

_NUM = re.compile(r"^-?\d+(\.\d+)?([eE][+-]?\d+)?$")


def _parse_val(raw: str) -> Optional[float]:
    """'34964.4' / '93%' / '5.43x' / '1.2e-07' -> float; else None."""
    s = raw.strip().rstrip("%x")
    if _NUM.match(s):
        return float(s)
    return None


def _load(path: str) -> Dict[str, dict]:
    with open(path) as f:
        data = json.load(f)
    rows = {}
    for r in data["rows"]:
        metrics = {}
        if r.get("us_per_call") is not None:
            metrics["us_per_call"] = float(r["us_per_call"])
        for kv in str(r.get("derived", "")).split(";"):
            if "=" in kv:
                k, v = kv.split("=", 1)
                val = _parse_val(v)
                if val is not None:
                    metrics[k] = val
        rows[r["name"]] = metrics
    return rows


def _check(gate: Gate, base: Optional[float],
           cur: Optional[float]) -> tuple:
    """-> (status, detail). status in {'ok','REGRESSED','MISSING','new'}"""
    if cur is None:
        return "MISSING", "row/metric absent from fresh run"
    if base is None:
        return "new", "no committed baseline yet"
    if gate.better == "higher":
        floor = base * (1 - gate.rel_tol) - gate.abs_tol
        if cur < floor:
            return "REGRESSED", f"{cur:g} < floor {floor:g}"
    else:
        ceil = base * (1 + gate.rel_tol) + gate.abs_tol
        if cur > ceil:
            return "REGRESSED", f"{cur:g} > ceiling {ceil:g}"
    return "ok", ""


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline-dir", default="benchmarks/baselines")
    ap.add_argument("--current-dir", default=".")
    ap.add_argument("--summary", default=None,
                    help="append the markdown diff table here "
                         "(e.g. $GITHUB_STEP_SUMMARY)")
    ap.add_argument("suites", nargs="*", default=None,
                    help="suite names to gate (default: all tracked)")
    args = ap.parse_args()

    suites = sorted({g.suite for g in GATES})
    if args.suites:
        suites = [s for s in suites if s in args.suites]

    lines = ["| suite | row | metric | baseline | current | status |",
             "|---|---|---|---|---|---|"]
    failed = []
    for suite in suites:
        cur_path = os.path.join(args.current_dir, f"BENCH_{suite}.json")
        base_path = os.path.join(args.baseline_dir, f"BENCH_{suite}.json")
        if not os.path.exists(cur_path):
            failed.append(f"{suite}: {cur_path} missing (suite not run?)")
            continue
        cur_rows = _load(cur_path)
        base_rows = _load(base_path) if os.path.exists(base_path) else {}
        for g in (g for g in GATES if g.suite == suite):
            base = base_rows.get(g.row, {}).get(g.metric)
            cur = cur_rows.get(g.row, {}).get(g.metric)
            status, detail = _check(g, base, cur)
            if status == "REGRESSED" and not g.hard:
                status = "advisory"
            mark = {"ok": "✅ ok", "new": "🆕 new",
                    "advisory": "⚠️ slow (advisory, not gating)",
                    "REGRESSED": "❌ REGRESSED",
                    "MISSING": "❌ MISSING"}[status]
            lines.append(
                f"| {suite} | {g.row} | {g.metric} | "
                f"{'-' if base is None else f'{base:g}'} | "
                f"{'-' if cur is None else f'{cur:g}'} | {mark}"
                f"{' — ' + detail if detail else ''} |"
            )
            if status in ("REGRESSED", "MISSING"):
                msg = f"{suite}/{g.row}/{g.metric}: {detail}"
                failed.append(f"{msg} ({g.note})" if g.note else msg)
    table = "\n".join(["## Bench regression gate", ""] + lines + [""])
    print(table)
    if args.summary:
        with open(args.summary, "a") as f:
            f.write(table + "\n")
    if failed:
        print("REGRESSIONS:", file=sys.stderr)
        for f_ in failed:
            print(f"  {f_}", file=sys.stderr)
        return 1
    print("bench gate: all tracked metrics within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
