"""Benchmark orchestrator — one module per paper table/figure. Prints
``name,us_per_call,derived`` CSV.

  bench_uts              — Fig 2/3/4: UTS-G scaling + efficiency
  bench_bc               — Fig 5/7/9: BC-G vs static scaling
  bench_bc_distribution  — Fig 6/8/10: workload distribution std-dev
  bench_params           — §2.4: w/z/n tuning space
  bench_kernels          — Pallas kernels vs oracles + CPU timings
  bench_moe_glb          — GLB applied to MoE expert placement
"""
import sys
import time
import traceback


def main() -> None:
    from benchmarks import (
        bench_bc, bench_bc_distribution, bench_kernels, bench_moe_glb,
        bench_params, bench_uts,
    )

    modules = [
        ("uts_scaling", bench_uts),
        ("bc_scaling", bench_bc),
        ("bc_distribution", bench_bc_distribution),
        ("glb_params", bench_params),
        ("kernels", bench_kernels),
        ("moe_glb", bench_moe_glb),
    ]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    for name, mod in modules:
        if only and only not in name:
            continue
        t0 = time.time()
        try:
            for row in mod.run():
                n, us, derived = row
                print(f"{n},{us:.1f},{derived}", flush=True)
        except Exception:  # noqa: BLE001
            traceback.print_exc()
            print(f"{name},nan,ERROR", flush=True)
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
