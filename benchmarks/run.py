"""Benchmark orchestrator — one module per paper table/figure. Prints
``name,us_per_call,derived`` CSV; with ``--json`` also writes
``BENCH_<suite>.json`` next to the CSV so the perf trajectory is
machine-readable (CI uploads the kernels and serve suites per PR).

  bench_uts              — Fig 2/3/4: UTS-G scaling + efficiency
  bench_bc               — Fig 5/7/9: BC-G vs static scaling
  bench_bc_distribution  — Fig 6/8/10: workload distribution std-dev
  bench_params           — §2.4: w/z/n tuning space
  bench_kernels          — Pallas kernels vs oracles + CPU timings
  bench_moe_glb          — GLB applied to MoE expert placement
  bench_serve            — engine decode loop: tokens/s + host syncs/token,
                           paged KV pool vs contiguous slots (throughput +
                           max concurrency at fixed HBM)

Each ``--json`` artifact carries a ``meta`` block — wall-clock start/end
(unix), host name, jax version, and the observability layer's
``clock_sync`` anchor (unix ↔ ``perf_counter`` µs) — so a bench row and
a ``BENCH_serve_trace.json`` event from the same run can be placed on
one timeline.

Usage: python benchmarks/run.py [suite-substring] [--json]
"""
import json
import platform
import sys
import time
import traceback


def main() -> None:
    import jax

    from benchmarks import (
        bench_bc, bench_bc_distribution, bench_kernels, bench_moe_glb,
        bench_params, bench_serve, bench_uts,
    )
    from repro.obs import clock_sync

    modules = [
        ("uts_scaling", bench_uts),
        ("bc_scaling", bench_bc),
        ("bc_distribution", bench_bc_distribution),
        ("glb_params", bench_params),
        ("kernels", bench_kernels),
        ("moe_glb", bench_moe_glb),
        ("serve", bench_serve),
    ]
    argv = sys.argv[1:]
    as_json = "--json" in argv
    argv = [a for a in argv if a != "--json"]
    only = argv[0] if argv else None
    failed = []
    print("name,us_per_call,derived")
    for name, mod in modules:
        if only and only not in name:
            continue
        t0 = time.time()
        rows = []
        try:
            for row in mod.run():
                n, us, derived = row
                rows.append({"name": n, "us_per_call": float(us),
                             "derived": str(derived)})
                print(f"{n},{us:.1f},{derived}", flush=True)
        except Exception:  # noqa: BLE001
            traceback.print_exc()
            print(f"{name},nan,ERROR", flush=True)
            rows.append({"name": name, "us_per_call": None,
                         "derived": "ERROR"})
            failed.append(name)
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
        if as_json:
            meta = {
                "started_unix": t0,
                "ended_unix": time.time(),
                "host": platform.node(),
                "jax_version": jax.__version__,
                # same clock domain the tracer stamps events in: lets a
                # trace ts line up against this suite's wall-clock rows
                "clock_sync": clock_sync(),
            }
            path = f"BENCH_{name}.json"
            with open(path, "w") as f:
                json.dump({"suite": name, "meta": meta, "rows": rows},
                          f, indent=2)
            print(f"# wrote {path}", flush=True)
    if failed:
        # A crashing suite must fail CI, not just leave an ERROR row in
        # the artifact.
        sys.exit(f"benchmark suites errored: {', '.join(failed)}")


if __name__ == "__main__":
    main()
