"""BC-G (paper §2.6): exact Brandes betweenness centrality on an SSCA2
R-MAT graph, GLB vs static partitioning — reproduces the paper's
workload-distribution comparison (Fig 6/8/10) in miniature.

    PYTHONPATH=src python examples/bc_demo.py [scale] [P]
"""
import sys

import numpy as np

from repro.core import GLBParams, run_sim
from repro.problems.bc import bc_problem
from repro.problems.rmat import brandes_bc_oracle, rmat_graph


def main():
    scale = int(sys.argv[1]) if len(sys.argv) > 1 else 6
    P = int(sys.argv[2]) if len(sys.argv) > 2 else 8

    adj, n = rmat_graph(scale=scale, seed=7)
    print(f"R-MAT scale={scale}: N={n}, edges={int(adj.sum())}")
    prob = bc_problem(adj, capacity=512)

    glb = run_sim(prob, P, GLBParams(n=4, steal_k=16), seed=0)
    static = run_sim(prob, P, GLBParams(n=4, no_steal=True), seed=0)

    bc = np.asarray(glb.result)
    if n <= 128:
        oracle = brandes_bc_oracle(adj)
        err = np.abs(bc - oracle).max()
        print(f"vs Brandes oracle: max abs err {err:.2e}")
    top = np.argsort(bc)[-5:][::-1]
    print("top-5 betweenness vertices:", top.tolist())

    for name, r in (("BC-G (GLB)", glb), ("BC (static)", static)):
        w = np.asarray(r.stats["processed"], np.float64)
        print(f"{name:12s}: makespan={int(r.supersteps):5d} supersteps, "
              f"work mean={w.mean():8.1f} std={w.std():8.2f}")
    np.testing.assert_allclose(
        np.asarray(glb.result), np.asarray(static.result), rtol=1e-4,
        atol=1e-3,
    )
    print("results identical; GLB flattens the distribution "
          "(paper Fig 6/8/10).")


if __name__ == "__main__":
    main()
