"""GLB quickstart — the paper's appendix Fibonacci example, verbatim in
spirit: provide process/split/merge/result + a root `init`, call run().

    PYTHONPATH=src python examples/quickstart.py [N] [P]
"""
import sys

from repro.core import GLB, GLBParams
from repro.problems.fib import fib_oracle, fib_problem


def main():
    n = int(sys.argv[1]) if len(sys.argv) > 1 else 18
    P = int(sys.argv[2]) if len(sys.argv) > 2 else 4

    glb = GLB(fib_problem(n), GLBParams(n=32, w=2, steal_k=32), P=P)
    result = glb.run(seed=0)
    print(f"fib-glb({n}) = {int(result)}   (oracle: {fib_oracle(n)})")
    print(f"supersteps: {glb.supersteps}")
    print(glb.stats_summary())


if __name__ == "__main__":
    main()
