"""Serving example: continuous batching across N replicas with the GLB
request balancer (paper's library applied to serving). All requests land on
replica 0; the balancer's lifeline matching redistributes them.

    PYTHONPATH=src python examples/serve_lm.py            # contiguous slots
    PYTHONPATH=src python examples/serve_lm.py --paged    # paged KV pool
    PYTHONPATH=src python examples/serve_lm.py --paged --prefix-cache \
        --prefill-chunk 8                                 # radix cache +
                                                          # chunked prefill
    PYTHONPATH=src python examples/serve_lm.py --paged --replicas 3 \
        --migrate                                         # live KV migration
    PYTHONPATH=src python examples/serve_lm.py --paged --replicas 3 \
        --chaos                                           # crash a replica,
                                                          # recover losslessly

With ``--paged`` each replica runs the block-granular KV pool + the
continuous-batching scheduler (admission, watermark preemption) and the
exit report includes pool occupancy/fragmentation. ``--prefix-cache``
adds the radix prefix cache — requests here share a system prompt, so
later admissions fork the cached blocks instead of re-prefilling them —
and the report gains hit-rate / prefill-tokens-saved lines.
``--prefill-chunk N`` splits long prompt prefills into N-token chunks
interleaved with decode. ``--migrate`` arms the balancer's second steal
tier: a replica whose queue is empty but whose slots are all busy sheds
*running* sequences — their written KV blocks travel to the thief and
decoding resumes there greedy-token-identically (DESIGN.md §9). The
run ends via GLB termination detection (the balance pass's load vector)
and prints the fabric-level merged stats report.

``--predictive`` attaches the per-tenant decode-length cost model
(DESIGN.md §16): the balancer diffuses predicted block-seconds off
overloaded replicas BEFORE anyone starves, with the reactive lifeline
tiers as backstop; the exit report gains a predictive line (diffusion
moves, predictions scored, mean |error|). ``--slo-admission`` (needs
``--slo`` with a ``ttft_ms`` or ``queue_wait_ms`` target and
``--paged``) makes each scheduler admit urgent-first by predicted SLO
slack and pace relaxed admissions.

``--trace PATH`` records the whole run — request lifecycle spans across
replicas, engine steps, prefill chunks, steal/migration events — as
Chrome trace_event JSON: open the file at https://ui.perfetto.dev.
``--flight N`` records into a bounded ring of N events instead (the
black-box default for always-on tracing; the dump is balanced even
after wraparound). ``--slo ttft_ms=250,tpot_ms=50`` declares latency
targets — the exit report then states attainment and any burn-rate
alerts. ``--metrics`` prints the merged fabric metrics registry (TTFT /
TPOT / queue-wait percentiles and all counters) in Prometheus text
format at exit. Traced runs finish with the analyzer's fabric report:
request time attribution, per-replica utilization, steal efficiency
(DESIGN.md §14). See also README "Analyzing a trace".
"""
import argparse
import time

import jax

from repro.configs import ARCHS
from repro.models import init_lm
from repro.obs import (FlightRecorder, SLOMonitor, Tracer, analyze_trace,
                       parse_slo_spec, render_summary,
                       validate_chrome_trace)
from repro.serve.engine import Engine, GLBReplicaBalancer, Request

SYSTEM_PROMPT = [7, 3, 9, 2, 5, 8, 6, 4, 1, 2, 3, 4, 9, 9, 8, 7]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--paged", action="store_true",
                    help="paged KV-cache pool + scheduler per replica")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="radix prefix cache (requires --paged)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunked prefill budget (requires --paged)")
    ap.add_argument("--replicas", type=int, default=2,
                    help="number of engine replicas in the fabric")
    ap.add_argument("--migrate", action="store_true",
                    help="steal LIVE sequences (KV migration) when a "
                         "victim's queue is empty but its slots are "
                         "saturated (requires --paged)")
    ap.add_argument("--predictive", action="store_true",
                    help="cost-modeled diffusive balancing: move "
                         "predicted block-seconds off overloaded "
                         "replicas before starvation fires "
                         "(DESIGN.md §16)")
    ap.add_argument("--slo-admission", action="store_true",
                    help="SLO-aware admission ordering/pacing per "
                         "replica (requires --paged and --slo with a "
                         "ttft_ms or queue_wait_ms target)")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="write a Perfetto-loadable Chrome trace JSON "
                         "of the run to PATH")
    ap.add_argument("--flight", metavar="N", type=int, default=None,
                    help="trace into a bounded ring of N events "
                         "(FlightRecorder) instead of an unbounded "
                         "tracer; implies tracing even without --trace")
    ap.add_argument("--slo", metavar="SPEC", default=None,
                    help="declare SLO targets, e.g. "
                         "'ttft_ms=250,tpot_ms=50' (optionally "
                         "'ttft_ms=250@0.999'); the exit report states "
                         "attainment and burn-rate alerts")
    ap.add_argument("--metrics", action="store_true",
                    help="print the merged fabric metrics registry "
                         "(Prometheus text format) at exit")
    ap.add_argument("--chaos", action="store_true",
                    help="crash replica 0 at superstep 2 (DESIGN.md "
                         "§15): the heartbeat detector fences it, "
                         "lifelines re-wire, and its requests are "
                         "re-admitted on the survivors with identical "
                         "greedy tokens (requires --paged, >= 2 "
                         "replicas)")
    args = ap.parse_args()

    cfg = ARCHS["tinyllama-1.1b"].smoke()
    params = init_lm(jax.random.key(0), cfg)
    kw = dict(max_slots=2, max_seq=64, pad_len=32, steps_per_sync=4)
    if args.paged:
        kw.update(paged=True, block_size=8,
                  prefix_cache=args.prefix_cache,
                  prefill_chunk=args.prefill_chunk)
    elif args.prefix_cache or args.prefill_chunk or args.migrate \
            or args.chaos:
        ap.error("--prefix-cache / --prefill-chunk / --migrate / "
                 "--chaos require --paged")
    if args.chaos and args.replicas < 2:
        ap.error("--chaos needs at least 2 replicas to survive")
    # ONE tracer for the whole fabric: request spans cross replicas.
    # --flight bounds it to a ring; a plain --trace keeps everything.
    if args.flight is not None:
        tracer = FlightRecorder(capacity=args.flight)
    elif args.trace:
        tracer = Tracer()
    else:
        tracer = None
    slo = SLOMonitor(parse_slo_spec(args.slo)) if args.slo else None
    if args.slo_admission:
        if not args.paged or slo is None:
            ap.error("--slo-admission requires --paged and --slo with "
                     "a ttft_ms or queue_wait_ms target")
        kw.update(slo=slo, slo_admission=True)
    cost_model = None
    if args.predictive:
        from repro.serve.cost import CostModel
        cost_model = CostModel()
    faults = None
    if args.chaos:
        from repro.serve.faults import FaultInjector
        faults = FaultInjector().crash(0, at=2)
    engines = [Engine(cfg, params, tracer=tracer, replica_id=i, **kw)
               for i in range(args.replicas)]
    bal = GLBReplicaBalancer(engines, migrate=args.migrate, tracer=tracer,
                             slo=slo, faults=faults,
                             cost_model=cost_model,
                             predictive=args.predictive)

    # Heterogeneous lengths: the first few requests run long, so replicas
    # that drew short ones go hungry while a peer is still wedged on
    # running sequences — the state only the --migrate tier can fix.
    reqs = [
        Request(rid=i, prompt=SYSTEM_PROMPT + [2 + i, 7, (3 * i) % cfg.vocab],
                max_new=(36 if i < 4 else 4) + (i % 3))
        for i in range(10)
    ]
    for r in reqs:
        bal.submit(r, rr=0)  # adversarial: everything on replica 0
    if args.migrate:
        # Wedge replica 0 first: drain its queue into running slots so
        # the balancer's LIVE tier (not just queue steals) is exercised.
        engines[0].step()

    t0 = time.time()
    status = bal.run(max_steps=500)
    dt = time.time() - t0
    assert status == "terminated", f"fabric {status}, not terminated"
    assert all(r.done for r in reqs)
    assert bal.terminated, "GLB termination must fire, not max_steps"
    if args.chaos:
        assert bal.replicas_dead == 1 and not bal.alive[0]
        print(f"chaos: replica 0 crashed and was declared dead; "
              f"{bal.readmitted_queued} queued + "
              f"{bal.readmitted_running} running request(s) re-admitted "
              f"on the survivors; zero requests lost")
    total = sum(e.tokens_out for e in engines)
    mode = "paged" if args.paged else "contiguous"
    if args.prefix_cache:
        mode += "+prefix-cache"
    if args.prefill_chunk:
        mode += f"+chunk{args.prefill_chunk}"
    if args.migrate:
        mode += "+migrate"
    if args.chaos:
        mode += "+chaos"
    if args.predictive:
        mode += "+predictive"
    if args.slo_admission:
        mode += "+slo-admission"
    print(f"[{mode}] completed {len(reqs)} requests, {total} tokens "
          f"in {dt:.1f}s over {args.replicas} replicas")
    for i, e in enumerate(engines):
        line = (f"  replica {i}: {e.tokens_out} tokens, {e.steps} steps, "
                f"peak {e.peak_running} concurrent")
        if args.paged:
            line += (f", peak pool occupancy {e.peak_occupancy:.2f}, "
                     f"peak fragmentation {e.peak_fragmentation:.2f}, "
                     f"{e.sched.admissions} admissions, "
                     f"{e.sched.preemptions} preemptions")
        if e.migrations_out or e.migrations_in:
            line += (f", {e.migrations_out} migrated out / "
                     f"{e.migrations_in} in")
        print(line)
        if args.paged and e.prefix_cache is not None:
            c = e.prefix_cache
            print(f"    prefix cache: {c.hits} hits / {c.misses} misses "
                  f"(hit rate {c.hit_rate:.0%}), "
                  f"{c.tokens_reused} prefill tokens saved, "
                  f"{c.evictions} evictions, "
                  f"{e.pool.cached_blocks} blocks cached now")
        if args.paged and e.sched.chunks_scheduled:
            print(f"    chunked prefill: {e.sched.chunks_scheduled} chunks")
    print()
    print(bal.report())
    for r in reqs[:3]:
        print(f"  req {r.rid}: {r.prompt} -> {r.out}")
    if tracer is not None:
        # Post-run analytics over the live tracer (dump() is balanced
        # and non-destructive): the fabric report from our own trace.
        analysis = analyze_trace(tracer)
        print()
        print(render_summary(analysis))
        if args.trace:
            tracer.write(args.trace)
            problems = validate_chrome_trace(tracer.dump())
            assert not problems, problems
            extra = (f" (ring: {tracer.dropped} dropped)"
                     if args.flight is not None else "")
            print(f"\nwrote {len(tracer.events)} trace events{extra} to "
                  f"{args.trace} — load it at https://ui.perfetto.dev, "
                  f"or: PYTHONPATH=src python -m repro.obs.analyze "
                  f"{args.trace}")
    if args.metrics:
        print("\n# merged fabric metrics registry")
        print(bal.merged_metrics().render_prometheus(), end="")


if __name__ == "__main__":
    main()
