"""Serving example: continuous batching across 2 replicas with the GLB
request balancer (paper's library applied to serving). All requests land on
replica 0; the balancer's lifeline matching redistributes them.

    PYTHONPATH=src python examples/serve_lm.py            # contiguous slots
    PYTHONPATH=src python examples/serve_lm.py --paged    # paged KV pool
    PYTHONPATH=src python examples/serve_lm.py --paged --prefix-cache \
        --prefill-chunk 8                                 # radix cache +
                                                          # chunked prefill

With ``--paged`` each replica runs the block-granular KV pool + the
continuous-batching scheduler (admission, watermark preemption) and the
exit report includes pool occupancy/fragmentation. ``--prefix-cache``
adds the radix prefix cache — requests here share a system prompt, so
later admissions fork the cached blocks instead of re-prefilling them —
and the report gains hit-rate / prefill-tokens-saved lines.
``--prefill-chunk N`` splits long prompt prefills into N-token chunks
interleaved with decode.
"""
import argparse
import time

import jax

from repro.configs import ARCHS
from repro.models import init_lm
from repro.serve.engine import Engine, GLBReplicaBalancer, Request

SYSTEM_PROMPT = [7, 3, 9, 2, 5, 8, 6, 4, 1, 2, 3, 4, 9, 9, 8, 7]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--paged", action="store_true",
                    help="paged KV-cache pool + scheduler per replica")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="radix prefix cache (requires --paged)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunked prefill budget (requires --paged)")
    args = ap.parse_args()

    cfg = ARCHS["tinyllama-1.1b"].smoke()
    params = init_lm(jax.random.key(0), cfg)
    kw = dict(max_slots=2, max_seq=64, pad_len=32)
    if args.paged:
        kw.update(paged=True, block_size=8,
                  prefix_cache=args.prefix_cache,
                  prefill_chunk=args.prefill_chunk)
    elif args.prefix_cache or args.prefill_chunk:
        ap.error("--prefix-cache / --prefill-chunk require --paged")
    engines = [Engine(cfg, params, **kw) for _ in range(2)]
    bal = GLBReplicaBalancer(engines)

    reqs = [
        Request(rid=i, prompt=SYSTEM_PROMPT + [2 + i, 7, (3 * i) % cfg.vocab],
                max_new=6 + (i % 5))
        for i in range(10)
    ]
    for r in reqs:
        bal.submit(r, rr=0)  # adversarial: everything on replica 0

    t0 = time.time()
    bal.run(max_steps=500)
    dt = time.time() - t0
    assert all(r.done for r in reqs)
    total = sum(e.tokens_out for e in engines)
    mode = "paged" if args.paged else "contiguous"
    if args.prefix_cache:
        mode += "+prefix-cache"
    if args.prefill_chunk:
        mode += f"+chunk{args.prefill_chunk}"
    print(f"[{mode}] completed {len(reqs)} requests, {total} tokens "
          f"in {dt:.1f}s")
    for i, e in enumerate(engines):
        line = (f"  replica {i}: {e.tokens_out} tokens, {e.steps} steps, "
                f"peak {e.peak_running} concurrent")
        if args.paged:
            line += (f", peak pool occupancy {e.peak_occupancy:.2f}, "
                     f"peak fragmentation {e.peak_fragmentation:.2f}, "
                     f"{e.sched.admissions} admissions, "
                     f"{e.sched.preemptions} preemptions")
        print(line)
        if args.paged and e.prefix_cache is not None:
            c = e.prefix_cache
            print(f"    prefix cache: {c.hits} hits / {c.misses} misses "
                  f"(hit rate {c.hit_rate:.0%}), "
                  f"{c.tokens_reused} prefill tokens saved, "
                  f"{c.evictions} evictions, "
                  f"{e.pool.cached_blocks} blocks cached now")
        if args.paged and e.sched.chunks_scheduled:
            print(f"    chunked prefill: {e.sched.chunks_scheduled} chunks")
    print(f"GLB moves: {bal.moves} (queued requests stolen by hungry "
          f"replica)")
    for r in reqs[:3]:
        print(f"  req {r.rid}: {r.prompt} -> {r.out}")


if __name__ == "__main__":
    main()
