"""End-to-end training driver example: train a small LM for a few hundred
steps with checkpointing; the loss must drop. Any assigned arch works via
--arch; presets scale it to laptop size.

CI-scale run (~2 min on 1 CPU core):
    PYTHONPATH=src python examples/train_lm.py

~100M-param run (same code path, bigger preset — hours on CPU, minutes on
a real accelerator):
    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b \
        --preset 100m --steps 300 --batch 8 --seq 512 --ckpt-dir /tmp/ck
"""
import sys
import tempfile

from repro.launch.train import train


def main():
    with tempfile.TemporaryDirectory() as d:
        args = [
            "--arch", "qwen2-1.5b", "--preset", "tiny",
            "--steps", "120", "--batch", "8", "--seq", "64",
            "--lr", "3e-3", "--ckpt-dir", d, "--ckpt-every", "50",
            "--log-every", "20",
        ] + sys.argv[1:]
        _, _, history = train(args)
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"loss: {first:.3f} -> {last:.3f}")
    assert last < first - 0.5, "training failed to reduce loss"
    print("OK: loss decreased.")


if __name__ == "__main__":
    main()
