"""UTS-G (paper §2.5): count a geometric tree under GLB, print the paper's
logging output + throughput/efficiency, compare against the oracle.

    PYTHONPATH=src python examples/uts_demo.py [depth] [P]
"""
import sys
import time

import numpy as np

from repro.core import GLB, GLBParams
from repro.problems.uts import uts_oracle, uts_problem


def main():
    depth = int(sys.argv[1]) if len(sys.argv) > 1 else 9
    P = int(sys.argv[2]) if len(sys.argv) > 2 else 8

    prob = uts_problem(b0=4.0, depth=depth, seed=19)
    params = GLBParams(n=256, w=2, steal_k=64)
    glb = GLB(prob, params, P=P)
    t0 = time.time()
    count = int(glb.run(seed=0))
    dt = time.time() - t0

    oracle = uts_oracle(b0=4.0, depth=depth, seed=19)
    assert count == oracle, (count, oracle)
    steps = glb.supersteps
    eff = count / (steps * P * params.n)  # work-unit efficiency per place
    print(f"UTS-G b0=4 d={depth} seed=19: {count} nodes "
          f"({count/dt:,.0f} nodes/s wall, {P} places)")
    print(f"supersteps: {steps}; superstep efficiency: {eff:.3f}")
    proc = np.asarray(glb.stats["processed"], np.float64)
    print(f"workload distribution: mean={proc.mean():.0f} "
          f"std={proc.std():.1f} (std/mean={proc.std()/proc.mean():.3f})")
    print(glb.stats_summary())


if __name__ == "__main__":
    main()
