"""UTS-G (paper §2.5): count a geometric tree under GLB, print the paper's
logging output + throughput/efficiency, compare against the oracle.

    PYTHONPATH=src python examples/uts_demo.py [depth] [P] [--trace PATH]

``--trace PATH`` runs the superstep loop under the observability tracer
(one jitted superstep per host iteration — numerically identical to the
fully-jitted loop) and writes Chrome trace_event JSON: per-superstep
spans plus the ``glb_load`` size-vector counter track, the same trace
vocabulary the serving fabric emits (examples/serve_lm.py --trace), so
taskbag runs and LM serving read in one Perfetto UI.
"""
import argparse
import time

import numpy as np

from repro.core import GLB, GLBParams
from repro.obs import Tracer, validate_chrome_trace
from repro.obs.analyze import analyze_trace, headline
from repro.problems.uts import uts_oracle, uts_problem


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("depth", type=int, nargs="?", default=9)
    ap.add_argument("P", type=int, nargs="?", default=8)
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="write a Perfetto-loadable Chrome trace JSON "
                         "of the superstep loop to PATH")
    args = ap.parse_args()
    depth, P = args.depth, args.P

    prob = uts_problem(b0=4.0, depth=depth, seed=19)
    params = GLBParams(n=256, w=2, steal_k=64)
    glb = GLB(prob, params, P=P)
    tracer = Tracer() if args.trace else None
    t0 = time.time()
    count = int(glb.run(seed=0, tracer=tracer))
    dt = time.time() - t0

    oracle = uts_oracle(b0=4.0, depth=depth, seed=19)
    assert count == oracle, (count, oracle)
    steps = glb.supersteps
    eff = count / (steps * P * params.n)  # work-unit efficiency per place
    print(f"UTS-G b0=4 d={depth} seed=19: {count} nodes "
          f"({count/dt:,.0f} nodes/s wall, {P} places)")
    print(f"supersteps: {steps}; superstep efficiency: {eff:.3f}")
    proc = np.asarray(glb.stats["processed"], np.float64)
    print(f"workload distribution: mean={proc.mean():.0f} "
          f"std={proc.std():.1f} (std/mean={proc.std()/proc.mean():.3f})")
    print(glb.stats_summary())
    if args.trace:
        tracer.write(args.trace)
        problems = validate_chrome_trace(tracer.to_chrome())
        assert not problems, problems
        print(headline(analyze_trace(tracer)))
        print(f"wrote {len(tracer.events)} trace events to {args.trace} "
              f"— load it at https://ui.perfetto.dev")


if __name__ == "__main__":
    main()
