"""repro — GLB (lifeline-based global load balancing) as a JAX/TPU framework.

The paper's contribution lives in repro.core; its workloads in
repro.problems; the LM training/serving stack that hosts the technique as a
first-class feature (MoE expert placement, serving-replica balancing) in
the sibling subpackages. See DESIGN.md / EXPERIMENTS.md at the repo root.
"""
from . import _jaxcompat  # noqa: F401  (backfills modern jax APIs on 0.4.x)

__version__ = "1.0.0"
