"""Compatibility layer for older jax releases (the image ships 0.4.37).

The codebase is written against the modern sharding surface — the
``jax.shard_map`` entry point, ``jax.sharding.AxisType`` /
``set_mesh`` / ``get_abstract_mesh``, ``jax.make_mesh(axis_types=...)``
and ``jax.tree.leaves_with_path`` — which landed after 0.4.37. Importing
this module backfills whichever of those are missing, delegating to the
equivalent 0.4.x APIs (``jax.experimental.shard_map``, mesh context
managers, ``jax.tree_util``). On a jax that already provides them this
module is a no-op, so the code keeps working unmodified after an upgrade.

Loaded from ``repro/__init__.py`` (any ``import repro...``) and from
``src/sitecustomize.py`` (any interpreter started with ``PYTHONPATH=src``,
which covers the subprocess-based multi-device tests that touch
``jax.sharding.AxisType`` before importing repro).

Nothing here initializes a backend: only module attributes are defined,
so ``XLA_FLAGS`` set after import (e.g. the forced host device count in
launch/dryrun.py and the subprocess tests) still takes effect.
"""
from __future__ import annotations

import contextlib
import enum
import functools
import threading


def _install() -> None:
    import jax
    import jax.sharding as jsharding
    import jax.tree_util as jtu

    # ------------------------------------------------ jax.sharding.AxisType
    if not hasattr(jsharding, "AxisType"):
        class AxisType(enum.Enum):
            Auto = "auto"
            Explicit = "explicit"
            Manual = "manual"

        jsharding.AxisType = AxisType

    # ------------------------------------------- jax.make_mesh(axis_types=)
    import inspect

    if "axis_types" not in inspect.signature(jax.make_mesh).parameters:
        _make_mesh = jax.make_mesh

        @functools.wraps(_make_mesh)
        def make_mesh(axis_shapes, axis_names, *, devices=None,
                      axis_types=None):
            # 0.4.x meshes are implicitly fully "auto"; the hint is dropped.
            del axis_types
            return _make_mesh(axis_shapes, axis_names, devices=devices)

        jax.make_mesh = make_mesh

    # ------------------------------------------------------- jax.shard_map
    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _shard_map

        def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True,
                      **kwargs):
            return _shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=bool(check_vma),
                              **kwargs)

        jax.shard_map = shard_map

    # -------------------------------- set_mesh / get_abstract_mesh ambient
    if not hasattr(jsharding, "set_mesh"):
        _state = threading.local()

        @contextlib.contextmanager
        def set_mesh(mesh):
            prev = getattr(_state, "mesh", None)
            _state.mesh = mesh
            try:
                # enter the legacy physical-mesh context too, so pjit picks
                # the mesh up for unspecified shardings
                with mesh:
                    yield mesh
            finally:
                _state.mesh = prev

        def get_abstract_mesh():
            m = getattr(_state, "mesh", None)
            if m is not None:
                return m
            try:
                from jax._src import mesh as mesh_lib

                phys = mesh_lib.thread_resources.env.physical_mesh
                if phys is not None and not phys.empty:
                    return phys
            except Exception:  # noqa: BLE001 - internal layout drift
                pass
            return None

        jsharding.set_mesh = set_mesh
        jsharding.get_abstract_mesh = get_abstract_mesh

    # ---------------------------------------------------- jax.lax.axis_size
    if not hasattr(jax.lax, "axis_size"):
        def axis_size(axis_name):
            from jax._src import core as _core

            # 0.4.x: axis_frame(name) IS the static int size of the axis
            return _core.axis_frame(axis_name)

        jax.lax.axis_size = axis_size

    # ------------------------------------------ jax.tree.leaves_with_path
    import jax.tree

    if not hasattr(jax.tree, "leaves_with_path"):
        jax.tree.leaves_with_path = jtu.tree_leaves_with_path
    if not hasattr(jax.tree, "flatten_with_path"):
        jax.tree.flatten_with_path = jtu.tree_flatten_with_path


_install()
