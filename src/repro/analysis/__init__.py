"""Roofline analysis: HLO collective parsing + term derivation + reports."""
