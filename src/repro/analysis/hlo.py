"""Optimized-HLO text analysis: collective-op operand bytes.

cost_analysis() gives FLOPs and bytes-accessed but no collective traffic;
we parse the post-SPMD optimized HLO and sum operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
(including their async -start forms; -done forms are skipped to avoid double
counting).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
}

# %name = dtype[dims]{layout} opcode(...)
_DEF_RE = re.compile(
    r"%?([\w\.\-]+)\s*=\s*\(?\s*([a-z0-9]+)\[([0-9,]*)\]"
)
# inline-typed operand: dtype[dims]{...} %name
_OPND_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+%?([\w\.\-]+)")

COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _nbytes(dtype: str, dims: str) -> int:
    if dtype not in DTYPE_BYTES:
        return 0
    n = DTYPE_BYTES[dtype]
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-opcode operand bytes of collective ops in one (per-device) module.

    Returns {"all-reduce": bytes, ..., "total": bytes, "count": n_ops}."""
    sizes: Dict[str, int] = {}
    out: Dict[str, int] = defaultdict(int)
    count = 0
    for line in hlo_text.splitlines():
        m = _DEF_RE.search(line)
        if m:
            sizes[m.group(1)] = _nbytes(m.group(2), m.group(3))
        stripped = line.strip()
        for op in COLLECTIVES:
            token = f" {op}("
            token_start = f" {op}-start("
            if token in stripped or token_start in stripped:
                if f" {op}-done(" in stripped:
                    continue
                # operand list between the first '(' after opcode and its ')'
                idx = stripped.index(token_start if token_start in stripped
                                     else token)
                args = stripped[idx:]
                args = args[args.index("(") + 1:]
                depth = 1
                end = 0
                for i, ch in enumerate(args):
                    if ch == "(":
                        depth += 1
                    elif ch == ")":
                        depth -= 1
                        if depth == 0:
                            end = i
                            break
                args = args[:end]
                b = 0
                inline = _OPND_RE.findall(args)
                if inline:
                    for dt, dims, _name in inline:
                        b += _nbytes(dt, dims)
                else:
                    for name in re.findall(r"%?([\w\.\-]+)", args):
                        b += sizes.get(name, 0)
                out[op] += b
                count += 1
                break
    out["total"] = sum(out[k] for k in COLLECTIVES if k in out)
    out["count"] = count
    return dict(out)
