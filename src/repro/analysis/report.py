"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from the
artifacts JSON written by launch/dryrun.py.

  PYTHONPATH=src python -m repro.analysis.report artifacts/dryrun
"""
from __future__ import annotations

import json
import os
import sys


def load(dirpath: str):
    recs = []
    for f in sorted(os.listdir(dirpath)):
        if f.endswith(".json"):
            with open(os.path.join(dirpath, f)) as fh:
                recs.append(json.load(fh))
    return recs


def fmt_bytes(b):
    if b >= 1e9:
        return f"{b/1e9:.2f}G"
    if b >= 1e6:
        return f"{b/1e6:.1f}M"
    return f"{b/1e3:.0f}K"


def dryrun_table(recs) -> str:
    lines = [
        "| arch | shape | mesh | status | compile_s | HBM/dev (args+tmp) | collectives (per-dev module) |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        mesh = "2x16x16" if r.get("multi_pod") else "16x16"
        if r["status"] == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {mesh} | SKIP (full-attn "
                f"500k, per spec) | — | — | — |"
            )
            continue
        if r["status"] != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {mesh} | ERROR "
                f"{r.get('error','')[:60]} | — | — | — |"
            )
            continue
        ma = r.get("memory_analysis", {})
        hbm = (ma.get("argument_size_in_bytes", 0)
               + ma.get("temp_size_in_bytes", 0)
               + ma.get("output_size_in_bytes", 0)
               - ma.get("alias_size_in_bytes", 0))
        cb = r.get("collective_bytes", {})
        coll = "+".join(
            f"{k.split('-')[-1][:4]}:{fmt_bytes(v)}"
            for k, v in sorted(cb.items())
            if k not in ("total", "count") and v
        )
        lines.append(
            f"| {r['arch']} | {r['shape']} | {mesh} | ok | "
            f"{r['compile_s']} | {fmt_bytes(hbm)} | {coll or '-'} |"
        )
    return "\n".join(lines)


def roofline_table(recs) -> str:
    lines = [
        "| arch | shape | bottleneck | t_comp (s) | t_mem (s) | t_coll (s) |"
        " useful ratio | roofline frac | note |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("multi_pod") or r["status"] != "ok":
            continue
        ro = r.get("roofline", {})
        note = ""
        cx = r.get("cost_extrapolated")
        if cx:
            note = cx.get("correction_note", "")
        lines.append(
            f"| {r['arch']} | {r['shape']} | **{ro.get('bottleneck','-')}** |"
            f" {ro.get('t_compute_s','-')} | {ro.get('t_memory_s','-')} |"
            f" {ro.get('t_collective_s','-')} | {ro.get('useful_ratio','-')} |"
            f" {ro.get('roofline_frac','-')} | {note} |"
        )
    return "\n".join(lines)


def main():
    d = sys.argv[1] if len(sys.argv) > 1 else "artifacts/dryrun"
    recs = load(d)
    ok = sum(r["status"] == "ok" for r in recs)
    skip = sum(r["status"] == "skipped" for r in recs)
    err = sum(r["status"] == "error" for r in recs)
    print(f"## Dry-run summary: {ok} ok, {skip} skipped (per spec), "
          f"{err} errors, {len(recs)} cells\n")
    print("### §Dry-run\n")
    print(dryrun_table(recs))
    print("\n### §Roofline (single-pod 16x16, 256 chips)\n")
    print(roofline_table(recs))


if __name__ == "__main__":
    main()
