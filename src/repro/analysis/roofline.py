"""Roofline terms from a compiled dry-run artifact (TPU v5e targets).

  compute    = HLO_FLOPs(per device) / peak_FLOP/s
  memory     = HLO_bytes(per device) / HBM_bw
  collective = collective operand bytes(per device) / link_bw

cost_analysis() and the parsed HLO both describe the per-device (post-SPMD)
module, so the spec's "X / (chips * BW)" with global X reduces to the
per-device form used here. MODEL_FLOPS = 6*N*D (6*N_active*D for MoE)
flags remat/redundancy waste via the useful-compute ratio.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

# TPU v5e-class hardware constants (per chip), per the assignment.
PEAK_FLOPS = 197e12      # bf16
HBM_BW = 819e9           # bytes/s
ICI_LINK_BW = 50e9       # bytes/s per link


@dataclasses.dataclass
class Roofline:
    flops: float                 # per-device HLO flops
    bytes_accessed: float        # per-device HLO bytes
    collective: Dict[str, int]   # per-device collective operand bytes
    chips: int
    model_flops: float           # 6*N(active)*tokens, GLOBAL
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    bottleneck: str = ""
    useful_ratio: float = 0.0    # MODEL_FLOPS / (HLO_FLOPs * chips)
    roofline_frac: float = 0.0   # useful work / (dominant time * peak)

    def finalize(self) -> "Roofline":
        self.t_compute = self.flops / PEAK_FLOPS
        self.t_memory = self.bytes_accessed / HBM_BW
        self.t_collective = self.collective.get("total", 0) / ICI_LINK_BW
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        self.bottleneck = max(terms, key=terms.get)
        total_hlo = self.flops * self.chips
        self.useful_ratio = (self.model_flops / total_hlo) if total_hlo else 0.0
        t_dom = max(terms.values())
        if t_dom > 0:
            # fraction of the compute roofline the step achieves if the
            # dominant term fully serializes (upper-bound-style estimate)
            self.roofline_frac = (
                self.model_flops / self.chips / PEAK_FLOPS
            ) / t_dom
        return self

    def row(self) -> Dict[str, object]:
        return {
            "t_compute_s": round(self.t_compute, 6),
            "t_memory_s": round(self.t_memory, 6),
            "t_collective_s": round(self.t_collective, 6),
            "bottleneck": self.bottleneck,
            "useful_ratio": round(self.useful_ratio, 4),
            "roofline_frac": round(self.roofline_frac, 4),
            "hlo_gflops_per_dev": round(self.flops / 1e9, 2),
            "hlo_gbytes_per_dev": round(self.bytes_accessed / 1e9, 3),
            "coll_mbytes_per_dev": round(
                self.collective.get("total", 0) / 1e6, 3
            ),
        }


def model_flops(cfg, shape) -> float:
    """6*N*D with D = tokens this step; MoE uses active params. Training
    counts fwd+bwd (the 6x); prefill/decode use the 2x forward-only factor."""
    n = cfg.active_param_count() if cfg.family == "moe" else cfg.param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # decode: 1 token/seq


def build(compiled, hlo_collective: Dict[str, int], chips: int,
          mflops: float) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older API returns [dict]
        cost = cost[0]
    return Roofline(
        flops=float(cost.get("flops", 0.0)),
        bytes_accessed=float(cost.get("bytes accessed", 0.0)),
        collective=hlo_collective,
        chips=chips,
        model_flops=mflops,
    ).finalize()
