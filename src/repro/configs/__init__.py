"""Assigned-architecture configs (exact published dims) + registry."""
from .registry import ARCHS, get_config, input_specs, cell_applicable
from repro.models.config import SHAPES

__all__ = ["ARCHS", "get_config", "input_specs", "cell_applicable", "SHAPES"]
