"""mamba2-130m [ssm] — SSD (state-space duality), attention-free.
24L d_model=768 vocab=50280, ssm_state=128 [arXiv:2405.21060; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    vocab=50280,
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    tie_embeddings=True,
)
