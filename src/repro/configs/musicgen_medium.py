"""musicgen-medium [audio] — decoder-only over EnCodec tokens.
48L d_model=1536 24H (GQA kv=24 = MHA) d_ff=6144 vocab=2048, 4 codebooks.
[arXiv:2306.05284; hf]. Frontend (EnCodec) is a stub: the model consumes
the 4 parallel token streams directly (delay-pattern handling lives in the
data pipeline, not the backbone)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab=2048,
    n_codebooks=4,
    rope_theta=10000.0,
)
