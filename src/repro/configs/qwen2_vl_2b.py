"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution.
28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936 [arXiv:2409.12191; hf].
Backbone only per spec: the vision tower is a stub — input_specs provide
precomputed merged patch+text embeddings plus (B,S,3) M-RoPE positions."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab=151936,
    qkv_bias=True,
    mrope=True,
    mrope_sections=(16, 24, 24),
    rope_theta=1000000.0,
)
