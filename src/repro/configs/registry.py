"""Architecture registry: --arch <id> resolution + input_specs per shape.

input_specs() returns ShapeDtypeStruct stand-ins for every model input of a
given (arch, shape) cell — weak-type-correct, shardable, zero allocation —
which is what the multi-pod dry-run lowers against.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, ShapeConfig

from .musicgen_medium import CONFIG as musicgen_medium
from .tinyllama_1_1b import CONFIG as tinyllama_1_1b
from .qwen1_5_110b import CONFIG as qwen1_5_110b
from .mistral_nemo_12b import CONFIG as mistral_nemo_12b
from .qwen2_1_5b import CONFIG as qwen2_1_5b
from .zamba2_7b import CONFIG as zamba2_7b
from .mamba2_130m import CONFIG as mamba2_130m
from .qwen2_vl_2b import CONFIG as qwen2_vl_2b
from .moonshot_v1_16b_a3b import CONFIG as moonshot_v1_16b_a3b
from .phi3_5_moe_42b import CONFIG as phi3_5_moe_42b

ARCHS: Dict[str, ModelConfig] = {
    c.name: c
    for c in (
        musicgen_medium,
        tinyllama_1_1b,
        qwen1_5_110b,
        mistral_nemo_12b,
        qwen2_1_5b,
        zamba2_7b,
        mamba2_130m,
        qwen2_vl_2b,
        moonshot_v1_16b_a3b,
        phi3_5_moe_42b,
    )
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def cell_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """long_500k decode runs only for bounded-state archs (spec)."""
    if shape.name.startswith("long") and not cfg.supports_long_context:
        return False, (
            "skipped: pure full-attention arch — a 524288-token KV cache "
            "decode is reserved for ssm/hybrid archs per spec "
            "(DESIGN.md §12)"
        )
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, object]:
    """ShapeDtypeStruct stand-ins for the cell's step function inputs."""
    B, S = shape.global_batch, shape.seq_len
    f = jax.ShapeDtypeStruct
    i32, bf16 = jnp.int32, jnp.bfloat16

    if shape.kind == "train":
        if cfg.family == "vlm":
            return {
                "embeds": f((B, S, cfg.d_model), bf16),
                "positions": f((B, S, 3), i32),
                "labels": f((B, S), i32),
            }
        if cfg.n_codebooks:
            return {"tokens": f((B, S, cfg.n_codebooks), i32)}
        return {"tokens": f((B, S), i32)}

    if shape.kind == "prefill":
        if cfg.family == "vlm":
            return {
                "embeds": f((B, S, cfg.d_model), bf16),
                "positions": f((B, S, 3), i32),
            }
        if cfg.n_codebooks:
            return {"tokens": f((B, S, cfg.n_codebooks), i32)}
        return {"tokens": f((B, S), i32)}

    # decode: one new token against a cache of size S
    from repro.models.transformer import make_cache

    cache = jax.eval_shape(lambda: make_cache(cfg, B, S))
    tok_shape = (B, 1, cfg.n_codebooks) if cfg.n_codebooks else (B, 1)
    return {
        "tokens": f(tok_shape, i32),
        "cache": cache,
        "cache_len": f((), i32),
    }
