"""zamba2-7b [hybrid] — Mamba2 backbone + weight-shared attention blocks.
81L d_model=3584 32H (kv=32) d_ff=14336 vocab=32000, ssm_state=64
[arXiv:2411.15242; unverified]. The shared (attn+MLP) block is applied every
6 mamba layers with tied weights (Zamba2's weight sharing); deviations noted
in DESIGN.md."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab=32000,
    ssm_state=64,
    ssm_headdim=64,
    ssm_expand=2,
    attn_every=6,
    rope_theta=10000.0,
)
