"""repro.core — the paper's contribution: lifeline-based global load balancing.

Layout:
  params.py    — GLBParams (the paper's w / z / n tunables + packet caps)
  taskbag.py   — array-backed TaskBag (the paper's default ArrayList bag)
  problem.py   — the TaskQueue/TaskBag user contract as pure-jnp functions
  lifeline.py  — lifeline topology + the deterministic steal matching
  scheduler.py — global-view superstep loop (simulated places)
  executor.py  — shard_map distributed executor (real mesh, collectives)
  stats.py     — the paper's per-worker logging counters
  api.py       — GLB facade (paper's ``GLB.run``)
"""
from .api import GLB
from .params import GLBParams
from .problem import GLBProblem
from .scheduler import run_sim, GLBRun
from .executor import run_shardmap, lower_shardmap, GLBDistRun
from .lifeline import (diffusion_pairs, lifeline_buddies, lifeline_mask,
                       match_steals, rewire_lifelines, terminated)
from .stats import fabric_summary, merge_place_stats

__all__ = [
    "GLB",
    "GLBParams",
    "GLBProblem",
    "GLBRun",
    "GLBDistRun",
    "run_sim",
    "run_shardmap",
    "lower_shardmap",
    "diffusion_pairs",
    "lifeline_buddies",
    "lifeline_mask",
    "match_steals",
    "rewire_lifelines",
    "terminated",
    "merge_place_stats",
    "fabric_summary",
]
