"""Public GLB API — mirrors the paper's ``new GLB[...](init, params); glb.run(start)``.

Users hand over a :class:`~repro.core.problem.GLBProblem` (the TaskQueue/
TaskBag contract) and pick an execution mode:

  mode="sim"       — P virtual places on the local device(s); used by the
                     paper-figure benchmarks to sweep place counts.
  mode="shard_map" — one place per device on a mesh axis; the production
                     path, lowered at 512 devices by the multi-pod dry-run.

Example (the paper's appendix, see examples/quickstart.py)::

    from repro.core import GLB, GLBParams
    from repro.problems.fib import fib_problem

    glb = GLB(fib_problem(n=20), GLBParams(n=32), P=8)
    result = glb.run(seed=0)
    print(result, glb.stats_summary())
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np

from .executor import run_shardmap
from .params import GLBParams
from .problem import GLBProblem
from .scheduler import run_sim
from .stats import summarize


class GLB:
    def __init__(
        self,
        problem: GLBProblem,
        params: GLBParams = GLBParams(),
        P: Optional[int] = None,
        mesh: Optional[jax.sharding.Mesh] = None,
        axis: str = "place",
        mode: str = "sim",
        routing: str = "dense",
    ):
        if mode == "sim" and P is None:
            raise ValueError("sim mode needs P (number of virtual places)")
        if mode == "shard_map" and mesh is None:
            raise ValueError("shard_map mode needs a mesh")
        self.problem = problem
        self.params = params
        self.P = P if P is not None else int(mesh.shape[axis])
        self.mesh = mesh
        self.axis = axis
        self.mode = mode
        self.routing = routing
        self.last_run = None

    def run(self, seed: int = 0, tracer: Any = None,
            faults: Any = None) -> Any:
        """Drive the problem to completion. ``tracer`` (sim mode only):
        a ``repro.obs.Tracer`` records per-superstep spans and the load
        vector — see ``run_sim``; the untraced path is unchanged (fully
        jitted ``lax.while_loop``). ``faults`` (sim mode only): a
        ``repro.serve.faults.FaultInjector`` — places crash/hang/slow
        mid-run and the failure protocol (heartbeats, lifeline
        re-wiring, bag recovery) keeps the answer exact."""
        if self.mode == "sim":
            out = run_sim(self.problem, self.P, self.params, seed=seed,
                          tracer=tracer, faults=faults)
        elif faults is not None:
            raise ValueError("fault injection is supported in mode='sim' only")
        elif tracer is not None and getattr(tracer, "enabled", False):
            raise ValueError("tracing is supported in mode='sim' only")
        else:
            out = run_shardmap(
                self.problem, self.mesh, self.params, seed=seed,
                axis=self.axis, routing=self.routing,
            )
        self.last_run = jax.device_get(out)
        if not bool(np.asarray(self.last_run.converged)):
            raise RuntimeError(
                f"GLB hit max_supersteps={self.params.max_supersteps} without "
                "draining; raise the bound or check capacity/steal settings"
            )
        return self.last_run.result

    @property
    def stats(self):
        return None if self.last_run is None else self.last_run.stats

    @property
    def supersteps(self) -> int:
        return -1 if self.last_run is None else int(self.last_run.supersteps)

    def stats_summary(self) -> str:
        if self.last_run is None:
            return "<not run>"
        return summarize(self.last_run.stats, self.supersteps)
