"""GLB parameter auto-tuning — the paper's future-work item (4): "Provide a
mechanism to auto-tune GLB parameters (e.g., task granularity, size of
random victims/lifeline buddies)".

Strategy: short probe runs in sim mode over a small (w, z, n) grid on a
scaled-down instance of the user's problem, scored by makespan (supersteps)
with idle fraction as the tie-breaker — the quantities the paper's log
exposes for manual tuning (§2.4). Deterministic given the seed.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import NamedTuple, Sequence

import numpy as np

from .params import GLBParams
from .problem import GLBProblem
from .scheduler import run_sim


class TuneResult(NamedTuple):
    best: GLBParams
    table: list  # (params, supersteps, idle_frac)


# ------------------------------------------------------- kernel block table
# Split-KV flash-decode block sizes, keyed by head_dim: how many KV cache
# rows one grid step streams through VMEM. The working set per step is
# ~2 * block_k * head_dim * 4B (k + v tiles, double-buffered by the
# pipeline), so wider heads take smaller blocks to stay well inside the
# ~16 MB VMEM budget; all entries are 128-multiples for MXU lane alignment.
DECODE_BLOCK_K = {32: 512, 64: 512, 128: 256, 256: 128}


def decode_block_k(kv_len: int, head_dim: int) -> int:
    """KV block size for kernels.flash_decode: table lookup by head_dim
    with a halving fallback so the block always divides the (bucketed)
    cache length."""
    return _block_from_table(DECODE_BLOCK_K, kv_len, head_dim)


# Paged KV pool block sizes (tokens per block), keyed by head_dim. The
# paged kernel streams exactly one pool block per grid step, so this is
# both the allocator granularity and the kernel tile: small enough that
# internal fragmentation (the partially-filled tail block per sequence)
# stays low at production request lengths, large enough that the (1, D) x
# (Bs, D)^T step keeps the MXU lanes busy and the per-block DMA amortizes.
# 4-8x smaller than DECODE_BLOCK_K — the contiguous kernel pays
# fragmentation at *bucket* granularity instead, so it wants big tiles.
PAGED_BLOCK_KV = {32: 64, 64: 64, 128: 32, 256: 16}


def paged_block_kv(max_seq: int, head_dim: int) -> int:
    """Pool/kernel block size for kernels.paged_decode: table lookup by
    head_dim, halved until it divides the per-sequence cache cap (the
    block-table width max_seq // block must be exact)."""
    return _block_from_table(PAGED_BLOCK_KV, max_seq, head_dim)


def _block_from_table(table: dict, length: int, head_dim: int) -> int:
    bk = min(table.values())
    for hd in sorted(table):
        if head_dim <= hd:
            bk = table[hd]
            break
    bk = max(1, min(bk, length))
    while length % bk:
        bk //= 2
    return max(bk, 1)


def autotune(
    problem: GLBProblem,
    P: int,
    base: GLBParams = GLBParams(),
    w_grid: Sequence[int] = (0, 1, 2, 4),
    z_grid: Sequence[int] = (0, 2),          # 0 => log2(P) cap
    n_grid: Sequence[int] = (32, 128, 512),
    seed: int = 0,
    max_supersteps: int = 50_000,
) -> TuneResult:
    rows = []
    for w, z, n in itertools.product(w_grid, z_grid, n_grid):
        params = dataclasses.replace(
            base, w=w, z=z, n=n, max_supersteps=max_supersteps
        )
        out = run_sim(problem, P, params, seed=seed)
        if not bool(np.asarray(out.converged)):
            continue
        steps = int(out.supersteps)
        idle = float(
            np.asarray(out.stats["idle_steps"]).sum() / max(steps * P, 1)
        )
        # score: wall-clock proxy = supersteps x per-superstep cost (~n)
        rows.append((params, steps, idle))
    if not rows:
        raise RuntimeError("no converging configuration found")
    rows.sort(key=lambda r: (r[1] * max(r[0].n, 1), r[2]))
    return TuneResult(best=rows[0][0], table=rows)
