"""Distributed GLB executor — shard_map over a real device mesh axis.

Same superstep semantics as ``scheduler.run_sim`` (asserted equivalent in
tests), but in per-place view with explicit collectives, which is what runs
on a pod and what the dry-run lowers at 512 devices:

  sizes    : ``lax.all_gather``  of one i32 per place          (steal requests)
  matching : replicated-deterministic (identical inputs everywhere)
  packets  : one ``lax.all_to_all`` over a (P, K, item) buffer (baseline
             routing; every unmatched row is zeros). See EXPERIMENTS.md §Perf
             for the hypercube-routed optimization that cuts these bytes.
  result   : ``lax.psum`` (or gather+fold) — the paper's ``reduce()``.

Determinism: the matching consumes only replicated values (gathered sizes,
superstep-folded key, pending matrix), so every device computes the identical
schedule — the APGAS request/response protocol with zero protocol messages.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from .lifeline import lifeline_buddies, match_steals
from .params import GLBParams
from .problem import GLBProblem
from .stats import FIELDS


class GLBDistRun(NamedTuple):
    result: Any
    per_place: Any
    stats: Dict[str, jax.Array]
    supersteps: jax.Array
    converged: jax.Array


def _select(cond: jax.Array, a: Any, b: Any) -> Any:
    return jax.tree.map(lambda x, y: jnp.where(cond, x, y), a, b)


def _route_dense(packet, dst_mine, src_mine, give, axis):
    """Baseline routing: (P, K, item) all_to_all, zeros off the matched row."""
    Psize = lax.axis_size(axis)
    dstc = jnp.clip(dst_mine, 0, Psize - 1)

    def scatter_row(v):
        buf = jnp.zeros((Psize,) + v.shape, v.dtype)
        row = jnp.where(
            jnp.asarray(give).reshape((1,) * v.ndim), v, jnp.zeros_like(v)
        )
        return buf.at[dstc].set(row)

    buf_items = {k: scatter_row(v) for k, v in packet["items"].items()}
    buf_count = (
        jnp.zeros((Psize,), jnp.int32)
        .at[dstc]
        .set(jnp.where(give, packet["count"], 0))
    )
    r_items = {
        k: lax.all_to_all(v, axis, 0, 0, tiled=True) for k, v in buf_items.items()
    }
    r_count = lax.all_to_all(buf_count, axis, 0, 0, tiled=True)

    take = src_mine >= 0
    srcc = jnp.clip(src_mine, 0, Psize - 1)
    return {
        "items": {k: v[srcc] for k, v in r_items.items()},
        "count": jnp.where(take, r_count[srcc], 0),
    }


def _route_lifeline_split(packet_ll, packet_rd, m, me, give_ll, give_rd,
                          axis, Psize, z):
    """Optimized routing (beyond-paper, EXPERIMENTS.md §Perf): lifeline
    steals always travel along a *static* edge — thief t's buddy i sits at
    (t + 2^i) mod P, so the packet hops exactly -2^i. One masked ``ppermute``
    per lifeline dimension routes all lifeline traffic collision-free
    (in-degree 1 per dimension). Only random-round steals keep the dense
    all_to_all, over a slimmer packet. Wire bytes drop from O(P·K) to
    O(z·K + P·K_rand) per place per superstep."""
    t_of_me = m.dst[me]                       # thief I serve (-1 none)
    dim_dist = (me - t_of_me) % Psize         # lifeline jump if serving one

    acc = {k: jnp.zeros_like(v) for k, v in packet_ll["items"].items()}
    acc_count = jnp.zeros((), jnp.int32)
    i_receive_ll = (m.src[me] >= 0) & m.via_lifeline[me]

    for i in range(z):
        # z = ceil(log2 P) keeps every jump 2^i < P, so jumps are distinct
        # and a receiver has in-degree exactly one per dimension.
        perm = [(p, (p - (1 << i)) % Psize) for p in range(Psize)]
        send_i = give_ll & (dim_dist == (1 << i))

        def ship(v, send=send_i):
            mask = jnp.asarray(send).reshape((1,) * v.ndim)
            return lax.ppermute(jnp.where(mask, v, jnp.zeros_like(v)), axis, perm)

        got = {k: ship(v) for k, v in packet_ll["items"].items()}
        got_count = lax.ppermute(jnp.where(send_i, packet_ll["count"], 0),
                                 axis, perm)
        # My buddy i is (me + 2^i); it sent iff it serves me via a lifeline.
        mine_i = i_receive_ll & (m.src[me] == (me + (1 << i)) % Psize)
        acc = {k: acc[k] + jnp.where(mine_i, got[k], jnp.zeros_like(got[k]))
               for k in acc}
        acc_count = acc_count + jnp.where(mine_i, got_count, 0)

    if packet_rd is None:  # pure-lifeline mode (w == 0)
        return {"items": acc, "count": acc_count}, None
    # Random-round remainder via the dense buffer, narrow packet.
    src_rd = jnp.where(m.via_lifeline[me], -1, m.src[me])
    inpkt_rd = _route_dense(packet_rd, m.dst[me], src_rd, give_rd, axis)
    return {"items": acc, "count": acc_count}, inpkt_rd


def build_place_fn(problem: GLBProblem, Psize: int, params: GLBParams,
                   axis: str, routing: str = "dense"):
    """Per-device GLB loop; call under shard_map/jit with a replicated key."""
    z = params.resolve_z(Psize)
    buddies_np = lifeline_buddies(Psize, z)
    max_steps = params.max_supersteps

    def place_fn(key):
        buddies = jnp.asarray(buddies_np)
        me = lax.axis_index(axis)
        state, bag = problem.init_place(me, Psize)
        carry = dict(
            state=state,
            bag=bag,
            pending=jnp.zeros((Psize, Psize), bool),
            step=jnp.zeros((), jnp.int32),
            done=jnp.zeros((), bool),
            stats={f: jnp.zeros((), jnp.int32) for f in FIELDS},
        )

        def cond(c):
            return (~c["done"]) & (c["step"] < max_steps)

        def body(c):
            state, bag, processed = problem.process(c["state"], c["bag"], params.n)
            my_size = bag["size"]
            if problem.work_in_state is not None:
                my_pend = problem.work_in_state(state).astype(jnp.int32)
            else:
                my_pend = jnp.zeros((), jnp.int32)
            # One gather carries both the stealable size and in-progress work.
            gathered = lax.all_gather(jnp.stack([my_size, my_pend]), axis)
            sizes, pend = gathered[:, 0], gathered[:, 1]
            hungry_all = (sizes + pend) == 0
            hungry = hungry_all[me]

            k_step = jax.random.fold_in(key, c["step"])
            m = match_steals(sizes, hungry_all, c["pending"], k_step, buddies,
                             params)

            thief = m.dst[me]
            give = thief >= 0
            if routing == "dense":
                bag_split, packet = problem.split(bag, params.steal_k)
                bag = _select(give, bag_split, bag)
                sent = jnp.where(give, packet["count"], 0)
                inpkt = _route_dense(packet, thief, m.src[me], give, axis)
                bag = problem.merge(bag, inpkt)
            elif routing == "lifeline":
                k_rand = params.steal_k_random or params.steal_k
                thief_c = jnp.clip(thief, 0, Psize - 1)
                give_ll = give & m.via_lifeline[thief_c]
                give_rd = give & ~m.via_lifeline[thief_c]
                bag_ll, packet_ll = problem.split(bag, params.steal_k)
                packet_ll["count"] = jnp.where(give_ll, packet_ll["count"], 0)
                if params.w == 0:
                    # pure-lifeline mode: every steal is single-hop static —
                    # the dense dynamic-routing buffer disappears entirely
                    bag = _select(give_ll, bag_ll, bag)
                    sent = packet_ll["count"]
                    inpkt_ll, _ = _route_lifeline_split(
                        packet_ll, None, m, me, give_ll, None,
                        axis, Psize, z)
                    bag = problem.merge(bag, inpkt_ll)
                    inpkt = {"count": inpkt_ll["count"]}
                else:
                    bag_rd, packet_rd = problem.split(bag, k_rand)
                    packet_rd["count"] = jnp.where(give_rd,
                                                   packet_rd["count"], 0)
                    bag = _select(give_ll, bag_ll,
                                  _select(give_rd, bag_rd, bag))
                    sent = packet_ll["count"] + packet_rd["count"]
                    inpkt_ll, inpkt_rd = _route_lifeline_split(
                        packet_ll, packet_rd, m, me, give_ll, give_rd,
                        axis, Psize, z)
                    bag = problem.merge(problem.merge(bag, inpkt_ll),
                                        inpkt_rd)
                    inpkt = {"count": inpkt_ll["count"] + inpkt_rd["count"]}
            else:
                raise ValueError(f"unknown routing {routing!r}")

            done = (sizes.sum() + pend.sum()) == 0

            got = m.src[me] >= 0
            st = c["stats"]
            stats = dict(
                processed=st["processed"] + processed.astype(jnp.int32),
                active_steps=st["active_steps"] + (processed > 0),
                idle_steps=st["idle_steps"] + hungry,
                steals_random=st["steals_random"] + (got & ~m.via_lifeline[me]),
                steals_lifeline=st["steals_lifeline"] + (got & m.via_lifeline[me]),
                served=st["served"] + give,
                items_sent=st["items_sent"] + sent,
                items_recv=st["items_recv"] + inpkt["count"],
                lifeline_regs=st["lifeline_regs"]
                + (m.pending[me] & ~c["pending"][me]).any(),
                max_size=jnp.maximum(st["max_size"], bag["size"]),
            )
            return dict(state=state, bag=bag, pending=m.pending,
                        step=c["step"] + 1, done=done, stats=stats)

        out = lax.while_loop(cond, body, carry)
        local = problem.result(out["state"])
        if problem.reduce_op == "sum":
            result = jax.tree.map(lambda x: lax.psum(x, axis), local)
        elif problem.reduce_op == "max":
            result = jax.tree.map(lambda x: lax.pmax(x, axis), local)
        elif problem.reduce_op == "min":
            result = jax.tree.map(lambda x: lax.pmin(x, axis), local)
        else:
            raise ValueError(problem.reduce_op)
        # Per-place outputs get a leading axis of 1 so out_specs can shard
        # them back onto the place axis.
        lead = lambda t: jax.tree.map(lambda x: x[None], t)
        return GLBDistRun(
            result=result,
            per_place=lead(local),
            stats=lead(out["stats"]),
            supersteps=out["step"],
            converged=out["done"],
        )

    return place_fn


def run_shardmap(
    problem: GLBProblem,
    mesh: Mesh,
    params: GLBParams = GLBParams(),
    seed: int = 0,
    axis: str = "place",
    routing: str = "dense",
) -> GLBDistRun:
    Psize = mesh.shape[axis]
    place_fn = build_place_fn(problem, Psize, params, axis, routing)
    shmapped = jax.shard_map(
        place_fn,
        mesh=mesh,
        in_specs=P(),  # replicated key
        out_specs=GLBDistRun(
            result=P(),
            per_place=P(axis),
            stats=P(axis),
            supersteps=P(),
            converged=P(),
        ),
        check_vma=False,
    )
    return jax.jit(shmapped)(jax.random.key(seed))


def lower_shardmap(problem, mesh, params, axis="place", routing="dense"):
    """AOT lowering entry point used by the multi-pod dry-run."""
    Psize = mesh.shape[axis]
    place_fn = build_place_fn(problem, Psize, params, axis, routing)
    shmapped = jax.shard_map(
        place_fn,
        mesh=mesh,
        in_specs=P(),
        out_specs=GLBDistRun(
            result=P(),
            per_place=P(axis),
            stats=P(axis),
            supersteps=P(),
            converged=P(),
        ),
        check_vma=False,
    )
    key = jax.eval_shape(lambda: jax.random.key(0))
    return jax.jit(shmapped).lower(key)
