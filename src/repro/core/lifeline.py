"""Lifeline topology + deterministic steal matching (paper §2.4, [23]).

The paper's protocol is asynchronous: an idle worker sends steal requests to
up to ``w`` random victims, then to its ``z`` lifeline buddies (a
z-dimensional hypercube); a buddy without work *remembers* the request and
pushes work when it gets some.

TPU adaptation (DESIGN.md §2): every place holds identical replicated inputs
each superstep — the gathered size vector, a superstep-folded PRNG key, and
the pending-lifeline matrix — so the request/response protocol collapses into
a *deterministic matching* computed redundantly on all places. The matching
pairs each hungry thief with at most one victim and each victim with at most
one thief per superstep (a partial permutation, which is what the collective
transfer layer routes).

Matching passes, in order:
  1. pending-lifeline service — buddies that now have work serve their oldest
     remembered request (the paper's "remember and push later");
  2. random round — each still-hungry thief tries its w fresh random victims;
  3. lifeline round — each still-hungry thief tries its z buddies in
     dimension order; unsatisfied edges are recorded in ``pending``.

Greedy conflict resolution iterates thieves in place order — deterministic,
and identical on every place. Thieves that received work have their pending
rows cleared (they are alive again).

Topology: buddy_i(p) = (p + 2^i) mod P for i < z — the standard cyclic
generalization of the hypercube used so P need not be a power of two; for
P = 2^z it is graph-isomorphic to the paper's hypercube (connected, degree z,
diameter <= z).
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from .params import GLBParams


def terminated(loads) -> bool:
    """GLB termination detection (paper §2.4: termination is *hidden*
    inside the protocol, not a separate barrier): the replicated load
    vector every place already gathers for the steal matching doubles as
    the termination detector — the computation is over exactly when
    ``all(load == 0)``. Callers fold this into their balance pass instead
    of running a second polling loop over the places."""
    return not bool(np.any(np.asarray(loads)))


def lifeline_buddies(P: int, z: int) -> np.ndarray:
    """Static (P, z) buddy table: buddy_i(p) = (p + 2^i) mod P."""
    p = np.arange(P)[:, None]
    i = np.arange(z)[None, :]
    return ((p + (1 << i)) % P).astype(np.int32)


def lifeline_mask(P: int, z: int) -> np.ndarray:
    """(P, P) bool — m[t, v] iff v is a lifeline buddy of t."""
    buddies = lifeline_buddies(P, z)
    m = np.zeros((P, P), dtype=bool)
    for t in range(P):
        m[t, buddies[t]] = True
    return m


def rewire_lifelines(alive, z: int) -> np.ndarray:
    """Post-failure buddy table: the 2^i circulant rebuilt over the
    SURVIVING place set (failure semantics, DESIGN.md §15).

    The table keeps the static (P, z) shape so jitted matching code
    never retraces on a death: dead rows point at themselves (inert —
    a dead place is never hungry and never advertises work, so a
    self-edge can neither match nor register a pending request), and
    alive rows jump 2^i hops along the compacted survivor ring, i.e.
    ``buddy_i(p) = survivors[(rank(p) + 2^i) % S]``. For S = P this is
    exactly ``lifeline_buddies(P, z)``. When the 2^i wrap collapses to
    a self-edge (2^i ≡ 0 mod S — z was sized for the original fabric),
    the ring neighbour stands in so every surviving row keeps z live
    outgoing lifelines and the survivor graph stays connected.
    """
    alive = np.asarray(alive, dtype=bool)
    P = alive.shape[0]
    survivors = np.flatnonzero(alive)
    S = survivors.size
    if S == 0:
        raise ValueError("rewire_lifelines: no surviving places")
    out = np.repeat(np.arange(P, dtype=np.int32)[:, None], z, axis=1)
    if S > 1:
        for r, p in enumerate(survivors):
            for i in range(z):
                b = survivors[(r + (1 << i)) % S]
                out[p, i] = b if b != p else survivors[(r + 1) % S]
    return out


def diffusion_pairs(costs, threshold: float, eligible=None):
    """Proactive donor→recipient pairing for predictive, cost-modeled
    balancing (DESIGN.md §16; arXiv 1909.07168 / 1308.0148).

    Where :func:`match_steals` is driven by *hungry* places (reactive:
    somebody already starved), diffusion is driven by *overloaded* ones:
    with ``costs`` the per-place predicted block-seconds, any place
    whose cost exceeds ``mean × (1 + threshold)`` becomes a donor and is
    paired with the cheapest eligible recipient strictly below the mean
    — moving work toward the balanced state BEFORE starvation fires.
    The reactive lifeline path stays as the backstop for whatever
    diffusion mispredicts.

    Pairing is greedy richest-donor-first, each recipient used at most
    once per pass (the same partial-permutation shape the transfer layer
    routes), ties broken by place index — deterministic, no PRNG, so the
    reactive matching's key-fold sequence is untouched by predictive
    mode. ``eligible`` masks recipients (dead or back-pressured places);
    donors need no mask because a dead place's cost is 0 and 0 can
    never exceed the mean threshold of a non-trivial fabric. Returns
    ``[(donor, recipient), ...]``; empty when balanced."""
    costs = np.asarray(costs, dtype=np.float64)
    P = costs.shape[0]
    if eligible is None:
        eligible = np.ones(P, dtype=bool)
    eligible = np.asarray(eligible, dtype=bool)
    mean = float(costs.mean())
    if mean <= 0.0:
        return []
    hi = mean * (1.0 + threshold)
    donors = sorted(np.flatnonzero(costs > hi).tolist(),
                    key=lambda p: (-costs[p], p))
    takers = sorted(
        np.flatnonzero(eligible & (costs < mean)).tolist(),
        key=lambda p: (costs[p], p))
    pairs = []
    for d in donors:
        if not takers:
            break
        r = takers.pop(0)
        if r == d:
            if not takers:
                break
            r = takers.pop(0)
        pairs.append((d, r))
    return pairs


class MatchResult(NamedTuple):
    src: jax.Array           # (P,) i32 — victim each thief receives from, -1 none
    dst: jax.Array           # (P,) i32 — thief each victim sends to, -1 none
    via_lifeline: jax.Array  # (P,) bool — thief matched via a lifeline edge
    pending: jax.Array       # (P, P) bool — updated pending-lifeline matrix


def match_steals(
    sizes: jax.Array,        # (P,) i32 — post-process STEALABLE bag sizes
    hungry: jax.Array,       # (P,) bool — no bag items AND no in-progress work
    pending: jax.Array,      # (P, P) bool — pending[t, v]: t waits on buddy v
    key: jax.Array,          # PRNG key, already folded with the superstep
    buddies: jax.Array,      # (P, z) i32 static buddy table
    params: GLBParams,
) -> MatchResult:
    P = sizes.shape[0]
    z = buddies.shape[1]
    w = params.w
    if params.no_steal:  # static-partitioning baseline: nobody ever steals
        neg = jnp.full((P,), -1, jnp.int32)
        return MatchResult(src=neg, dst=neg,
                           via_lifeline=jnp.zeros((P,), bool),
                           pending=pending)
    can_give = sizes >= max(params.min_give, 1)

    neg = jnp.full((P,), -1, jnp.int32)
    init = dict(
        claimed=~can_give,                  # victims already unusable are "claimed"
        matched=~hungry,                    # non-hungry places never steal
        src=neg,
        dst=neg,
        via=jnp.zeros((P,), bool),
    )

    def _claim(state, t, v, found, via_lifeline):
        """Pair thief t with victim v if `found` (all P-length updates)."""
        do = found & ~state["matched"][t]
        v = jnp.clip(v, 0, P - 1)
        return dict(
            claimed=state["claimed"].at[v].set(state["claimed"][v] | do),
            matched=state["matched"].at[t].set(state["matched"][t] | do),
            src=state["src"].at[t].set(jnp.where(do, v, state["src"][t])),
            dst=state["dst"].at[v].set(jnp.where(do, t, state["dst"][v])),
            via=state["via"].at[t].set(jnp.where(do, via_lifeline, state["via"][t])),
        )

    # ---- pass 1: serve remembered lifeline requests (oldest edge = lowest v)
    def pass1(t, state):
        row = pending[t] & ~state["claimed"]
        v = jnp.argmin(jnp.where(row, jnp.arange(P), P))
        found = row.any() & ~state["matched"][t]
        return _claim(state, t, v, found, jnp.bool_(True))

    state = jax.lax.fori_loop(0, P, pass1, init)

    # ---- pass 2: random round — w fresh victims per thief (never self)
    if P > 1 and w > 0:
        cand = (jnp.arange(P)[:, None]
                + 1 + jax.random.randint(key, (P, w), 0, P - 1)) % P

        def pass2(t, state):
            for i in range(w):  # static unroll, w is small
                v = cand[t, i]
                found = ~state["claimed"][v]
                state = _claim(state, t, v, found, jnp.bool_(False))
            return state

        state = jax.lax.fori_loop(0, P, pass2, state)

    # ---- pass 3: lifeline round — buddies in dimension order
    def pass3(t, state):
        for i in range(z):  # static unroll, z <= log2(P)
            v = buddies[t, i]
            found = ~state["claimed"][v]
            state = _claim(state, t, v, found, jnp.bool_(True))
        return state

    state = jax.lax.fori_loop(0, P, pass3, state)

    # ---- pending update: unmatched hungry thieves (re-)register their
    # lifelines; thieves that got work clear their outstanding requests.
    # Derived from the `buddies` ARGUMENT (not the static P,z table):
    # after a failure re-wire the pending edges must re-register toward
    # the surviving buddy set, never toward a dead place. Self-edges
    # (dead rows point at themselves) register nothing.
    ll_mask = jnp.zeros((P, P), bool).at[
        jnp.arange(P)[:, None], buddies
    ].set(True) & ~jnp.eye(P, dtype=bool)
    unmatched = hungry & ~state["matched"]
    new_pending = (pending | (ll_mask & unmatched[:, None])) & ~state["matched"][:, None]
    # A pending edge only makes sense towards a buddy; rows of non-hungry
    # places were cleared above (matched includes them).

    src = jnp.where(hungry, state["src"], -1)
    return MatchResult(src=src, dst=state["dst"], via_lifeline=state["via"],
                       pending=new_pending)
