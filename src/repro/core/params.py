"""GLB tunables — the paper's user-facing knobs (§2.4).

The paper exposes three parameters:
  w — number of random victims tried per steal round,
  z — number of lifeline buddies (dimension of the lifeline hypercube),
  n — task granularity: how many task items ``process(n)`` handles between
      network probes (here: per superstep).

We add two knobs that exist implicitly in the paper's implementation:
  steal_k  — max items per steal packet (the paper ships "half the bag"; on a
             static-collective machine the packet must be bounded — interval
             task items still carry ~half the *work*, see DESIGN.md §2),
  min_give — the minimum bag size at which a place is considered a viable
             victim.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class GLBParams:
    w: int = 2                # random victims per round (paper: w)
    z: int = 0                # lifeline dims; 0 => ceil(log2(P)) at runtime
    n: int = 64               # task granularity per superstep (paper: n)
    steal_k: int = 64         # max items per steal packet
    steal_k_random: int = 0   # packet cap for random-round steals under
                              # routing='lifeline' (0 => steal_k)
    min_give: int = 1         # victim viability threshold (bag size)
    max_supersteps: int = 1_000_000  # safety bound on the while_loop
    no_steal: bool = False    # disable balancing entirely — the "legacy
                              # static partitioning" baseline of paper §3.6
    heartbeat_misses: int = 3  # consecutive missed load-vector gathers
                               # before a place is declared dead (the
                               # failure-detection window, DESIGN.md §15)

    def resolve_z(self, P: int) -> int:
        # Cap at ceil(log2 P): beyond that the circulant jumps 2^i wrap and
        # duplicate buddies (and break single-hop lifeline routing).
        cap = max(1, math.ceil(math.log2(max(2, P))))
        if self.z > 0:
            return min(self.z, cap)
        return cap


DEFAULT = GLBParams()
