"""The GLB user contract — the paper's TaskQueue/TaskBag interface (§2.3).

The paper asks users for sequential pieces of code:
  process(n)  — compute up to n task items, return whether work remains;
  split()     — give away part of the bag (None if too small);
  merge(tb)   — absorb an incoming bag;
  getResult() — local result;
  reduce()    — associative+commutative reduction across places;
plus an optional ``init`` that seeds the root task at place 0.

Here the same contract is a bundle of *pure jnp functions* operating on
explicit (state, bag) pytrees so GLB can run them under ``vmap`` (simulated
places) or ``shard_map`` (real devices). ``process`` takes an explicit budget
and returns partial progress — the paper's "interruptable state machine"
refinement (§2.6) is the norm here, which bounds steal-response latency by
construction.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax

State = Any
Bag = Dict[str, Any]
Packet = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class GLBProblem:
    """A GLB-schedulable problem. All callables are pure and jit-safe.

    init_place(p, P)        -> (state, bag) for place index p (traced i32).
                               Root-style problems put the root task at p==0;
                               statically-partitionable problems pre-split.
    process(state, bag, n)  -> (state, bag, processed:i32). Handles at most n
                               work units; must be a no-op on an empty bag.
    split(bag, K)           -> (bag, packet). Packet carries <= K items and
                               its own count; count==0 means "nothing to give"
                               (the paper's `split() == null`).
    merge(bag, packet)      -> bag. Must be a no-op for count==0.
    result(state)           -> result pytree (reduced across places).
    reduce_op               — 'sum' | 'max' | 'min' (assoc.+comm., §2.1).
    capacity                — bag capacity incl. slack for one merge packet.
    work_in_state(state)    -> i32 count of in-progress, non-stealable work
                               held in `state` (the paper's §2.6 interruptable
                               state machine mid-vertex). Counted for hunger
                               and termination, but not stealable. Optional.
    evacuate(state, bag)    -> (state, bag). Crash recovery (DESIGN.md §15):
                               push any in-progress work held in `state` back
                               into the bag as ordinary items so a dead
                               place's bag drain captures ALL of its
                               outstanding work; must leave the state with
                               work_in_state == 0. Required for fault
                               injection whenever work_in_state is set;
                               problems without in-state work don't need it.
    """

    name: str
    item_spec: Dict[str, jax.ShapeDtypeStruct]
    capacity: int
    init_place: Callable[[jax.Array, int], Tuple[State, Bag]]
    process: Callable[[State, Bag, int], Tuple[State, Bag, jax.Array]]
    split: Callable[[Bag, int], Tuple[Bag, Packet]]
    merge: Callable[[Bag, Packet], Bag]
    result: Callable[[State], Any]
    reduce_op: str = "sum"
    work_in_state: Callable[[State], jax.Array] | None = None
    evacuate: Callable[[State, Bag], Tuple[State, Bag]] | None = None
