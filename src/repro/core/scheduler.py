"""Global-view GLB superstep scheduler (simulated places).

Runs P *virtual places* on however many real devices exist (typically one):
every per-place array carries a leading P axis, per-place user code is
``vmap``-ed, and the balance phase is plain array indexing. This is the
reference semantics of the distributed executor (``executor.py``) — the two
are asserted equivalent in tests — and is what the paper-figure benchmarks
sweep over place counts with.

One superstep (see DESIGN.md §2 for the X10 -> BSP mapping):
  1. every place runs ``process(n)``           (paper: work between probes)
  2. bag sizes are exchanged                   (paper: steal requests)
  3. deterministic matching pairs thieves/victims (random + lifeline rounds)
  4. victims ``split``, packets routed, thieves ``merge``
  5. global termination check (sum of sizes == 0)
"""
from __future__ import annotations

import contextlib
from typing import Any, Dict, NamedTuple, Optional

import numpy as np
import jax
import jax.numpy as jnp

from . import taskbag as tb
from .lifeline import lifeline_buddies, match_steals, rewire_lifelines
from .params import GLBParams
from .problem import GLBProblem
from .stats import init_stats, update_stats


class GLBRun(NamedTuple):
    result: Any                   # reduced result (the paper's `reduce()`)
    per_place: Any                # per-place results, leading P axis
    stats: Dict[str, jax.Array]   # per-place counters, leading P axis
    supersteps: jax.Array         # i32
    converged: jax.Array          # bool — False only if max_supersteps hit


def _select(cond_p: jax.Array, a: Any, b: Any) -> Any:
    """Per-place select over pytrees with leading P axis."""
    def sel(x, y):
        c = cond_p.reshape(cond_p.shape + (1,) * (x.ndim - 1))
        return jnp.where(c, x, y)
    return jax.tree.map(sel, a, b)


def reduce_result(per_place: Any, op: str) -> Any:
    if op == "sum":
        return jax.tree.map(lambda x: x.sum(axis=0), per_place)
    if op == "max":
        return jax.tree.map(lambda x: x.max(axis=0), per_place)
    if op == "min":
        return jax.tree.map(lambda x: x.min(axis=0), per_place)
    raise ValueError(f"unknown reduce op {op!r}")


def run_sim(
    problem: GLBProblem,
    P: int,
    params: GLBParams = GLBParams(),
    seed: int = 0,
    max_supersteps: Optional[int] = None,
    tracer=None,
    faults=None,
) -> GLBRun:
    """Execute `problem` on P simulated places. Fully jit-compiled.

    With an enabled ``tracer`` (``repro.obs.Tracer``), the SAME jitted
    superstep body runs under a host loop instead of ``lax.while_loop``,
    emitting one ``superstep`` span and a ``glb_load`` counter per
    iteration (one device->host sync each — the traced path trades a
    sync per superstep for the timeline; results are numerically
    identical, asserted in ``tests/test_obs.py``).

    With a ``faults`` injector (``repro.serve.faults.FaultInjector`` —
    one chaos harness for both workload shapes, DESIGN.md §15), the
    host loop also runs the failure protocol: per superstep each place
    is asked for a heartbeat; a place missing ``params.heartbeat_misses``
    consecutive gathers is declared dead — its in-state work is
    evacuated back into its bag (``problem.evacuate``), the bag is
    drained wholesale into the survivors with the most headroom, its
    pending rows/columns are cleared, and the lifeline table is rebuilt
    over the survivors (``rewire_lifelines``). Faulted-but-undeclared
    places are simply frozen (not processed, not matched), which IS the
    last-known-load rule: their unchanged bag size keeps termination
    from firing while they hold work. Accumulated per-place results
    survive a death (the collector model: results are flushed at each
    gather)."""
    z = params.resolve_z(P)
    buddies = jnp.asarray(lifeline_buddies(P, z))
    max_steps = max_supersteps or params.max_supersteps
    if faults is not None and problem.work_in_state is not None \
            and problem.evacuate is None:
        raise ValueError(
            f"problem {problem.name!r} holds in-state work but defines "
            f"no evacuate hook; its mid-item window is not survivable"
        )

    vprocess = jax.vmap(problem.process, in_axes=(0, 0, None))
    vsplit = jax.vmap(problem.split, in_axes=(0, None))
    vmerge = jax.vmap(problem.merge)

    def init_carry():
        states, bags = jax.vmap(lambda p: problem.init_place(p, P))(
            jnp.arange(P, dtype=jnp.int32)
        )
        return dict(
            states=states,
            bags=bags,
            pending=jnp.zeros((P, P), bool),
            step=jnp.zeros((), jnp.int32),
            done=jnp.zeros((), bool),
            stats=init_stats(P),
        )

    def _body(c, key, bud, proc, active):
        """One superstep, parameterized for the failure protocol:
        ``bud`` is the (possibly re-wired) buddy table, ``proc`` masks
        places that make compute progress this superstep, ``active``
        masks places that answer the gather (may be matched). With
        all-True masks and the static table this is exactly the
        original no-fault superstep — the masks constant-fold."""
        # 1. process (frozen places keep their state/bag verbatim)
        states_n, bags_n, processed = vprocess(
            c["states"], c["bags"], params.n
        )
        states = _select(proc, states_n, c["states"])
        bags = _select(proc, bags_n, c["bags"])
        processed = jnp.where(proc, processed, 0)
        sizes = bags["size"]
        # In-progress, non-stealable work held in state (paper §2.6's
        # interruptable state machine) counts for hunger/termination.
        if problem.work_in_state is not None:
            pend = jax.vmap(problem.work_in_state)(states).astype(jnp.int32)
        else:
            pend = jnp.zeros_like(sizes)
        # Dead/unresponsive places neither give nor take this round, but
        # their (frozen) work still blocks termination below.
        hungry = ((sizes + pend) == 0) & active

        # 2-3. match thieves to victims (replicated-deterministic)
        k_step = jax.random.fold_in(key, c["step"])
        m = match_steals(jnp.where(active, sizes, 0), hungry,
                         c["pending"], k_step, bud, params)

        # 4. transfer: victims split, packets routed, thieves merge
        bags_split, packets = vsplit(bags, params.steal_k)
        give = m.dst >= 0
        packets["count"] = jnp.where(give, packets["count"], 0)
        bags = _select(give, bags_split, bags)

        srcc = jnp.clip(m.src, 0, P - 1)
        recv = jax.tree.map(lambda x: x[srcc], packets)
        recv["count"] = jnp.where(m.src >= 0, recv["count"], 0)
        bags = vmerge(bags, recv)

        # 5. termination: if no work existed post-process, none was
        # transferred either (victims need size>0), so this is exact.
        done = (sizes.sum() + pend.sum()) == 0

        stats = update_stats(
            c["stats"],
            processed=processed,
            hungry=hungry,
            src=m.src,
            via_lifeline=m.via_lifeline,
            dst=m.dst,
            sent=packets["count"],
            recv=recv["count"],
            registered=(m.pending & ~c["pending"]).any(axis=1),
            sizes=bags["size"],
        )
        return dict(
            states=states,
            bags=bags,
            pending=m.pending,
            step=c["step"] + 1,
            done=done,
            stats=stats,
        )

    def body(c, key):
        ones = jnp.ones((P,), bool)
        return _body(c, key, buddies, ones, ones)

    def finish(out) -> GLBRun:
        per_place = jax.vmap(problem.result)(out["states"])
        result = reduce_result(per_place, problem.reduce_op)
        return GLBRun(
            result=result,
            per_place=per_place,
            stats=out["stats"],
            supersteps=out["step"],
            converged=out["done"],
        )

    traced = tracer is not None and getattr(tracer, "enabled", False)
    if not traced and faults is None:
        def _run(key):
            def cond(c):
                return (~c["done"]) & (c["step"] < max_steps)

            out = jax.lax.while_loop(cond, lambda c: body(c, key),
                                     init_carry())
            return finish(out)

        return jax.jit(_run)(jax.random.key(seed))

    # Host-loop path (traced and/or faulted): the SAME jitted body —
    # identical key folding and superstep recurrence, so no-fault
    # results match the jitted while_loop bit-for-bit; the loop
    # condition mirrors ``cond`` above.
    if traced:
        tracer.process_name(0, f"GLB sim ({P} places)")
        tracer.thread_name(0, 0, "supersteps")

    def _put(full, one, idx):
        """Write a single place's pytree back into the leading-P tree."""
        return jax.tree.map(lambda f, o: f.at[idx].set(o), full, one)

    def _on_death(carry, p, alive):
        """Failure recovery for place p (all host-side; deaths are rare
        so eager jnp is fine): evacuate in-state work, drain the bag
        wholesale into the survivors with the most headroom, clear the
        dead place's pending rows/columns. Whole ITEMS move (the
        generic tail split), never problem.split — interval-halving can
        refuse single-child items, which would strand work on a corpse."""
        states, bags = carry["states"], carry["bags"]
        if problem.evacuate is not None:
            ev_s, ev_b = jax.vmap(problem.evacuate)(states, bags)
            onehot = jnp.arange(P) == p
            states = _select(onehot, ev_s, states)
            bags = _select(onehot, ev_b, bags)
        moved = 0
        while True:
            sizes = np.asarray(jax.device_get(bags["size"]))
            if sizes[p] == 0:
                break
            surv = np.flatnonzero(alive)
            tgt = int(surv[np.argmin(sizes[surv])])
            take = min((int(sizes[p]) + 1) // 2, params.steal_k)
            if int(sizes[tgt]) + take > problem.capacity:
                raise RuntimeError(
                    f"place {p} died with {int(sizes[p])} items but no "
                    f"survivor has headroom for a {take}-item packet"
                )
            bag_p = jax.tree.map(lambda x: x[p], bags)
            bag_p, pkt = tb.split_tail_half(bag_p, params.steal_k)
            bag_t = problem.merge(jax.tree.map(lambda x: x[tgt], bags), pkt)
            bags = _put(_put(bags, bag_p, p), bag_t, tgt)
            moved += int(jax.device_get(pkt["count"]))
        pending = carry["pending"].at[p, :].set(False).at[:, p].set(False)
        if traced:
            tracer.instant("bag_recovered", pid=0,
                           args={"place": p, "items": moved})
        return dict(carry, states=states, bags=bags, pending=pending)

    alive = np.ones(P, bool)
    misses = np.zeros(P, np.int32)
    bud = buddies
    step_fn = jax.jit(_body)
    key = jax.random.key(seed)
    carry = jax.jit(init_carry)()
    ones = np.ones(P, bool)
    while (not bool(carry["done"])) and int(carry["step"]) < max_steps:
        step_i = int(carry["step"])
        proc, active = ones, ones
        if faults is not None:
            faults.begin_superstep(step_i)
            for p in range(P):
                if not alive[p]:
                    continue
                if faults.responsive(p):
                    misses[p] = 0
                    continue
                misses[p] += 1
                if misses[p] >= params.heartbeat_misses:
                    alive[p] = False
                    misses[p] = 0
                    if not alive.any():
                        raise RuntimeError("every place has died")
                    if traced:
                        tracer.instant(
                            "place_dead", pid=0,
                            args={"place": p, "superstep": step_i,
                                  "window": params.heartbeat_misses},
                        )
                    carry = _on_death(carry, p, alive)
                    bud = jnp.asarray(rewire_lifelines(alive, z))
            proc = alive & np.asarray(
                [faults.should_step(p) for p in range(P)]
            )
            active = alive & np.asarray(
                [faults.responsive(p) for p in range(P)]
            )
        span = (tracer.span("superstep", pid=0, args={"n": step_i})
                if traced else contextlib.nullcontext())
        with span:
            carry = step_fn(carry, key, bud, jnp.asarray(proc),
                            jnp.asarray(active))
            if traced:
                sizes = jax.device_get(carry["bags"]["size"])
                vals = {"total": float(sizes.sum()),
                        "hungry": float((sizes == 0).sum())}
                if P <= 16:
                    vals.update({f"place{i}": float(v)
                                 for i, v in enumerate(sizes)})
                tracer.counter("glb_load", vals, pid=0)
    return jax.jit(finish)(carry)
