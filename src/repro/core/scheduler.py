"""Global-view GLB superstep scheduler (simulated places).

Runs P *virtual places* on however many real devices exist (typically one):
every per-place array carries a leading P axis, per-place user code is
``vmap``-ed, and the balance phase is plain array indexing. This is the
reference semantics of the distributed executor (``executor.py``) — the two
are asserted equivalent in tests — and is what the paper-figure benchmarks
sweep over place counts with.

One superstep (see DESIGN.md §2 for the X10 -> BSP mapping):
  1. every place runs ``process(n)``           (paper: work between probes)
  2. bag sizes are exchanged                   (paper: steal requests)
  3. deterministic matching pairs thieves/victims (random + lifeline rounds)
  4. victims ``split``, packets routed, thieves ``merge``
  5. global termination check (sum of sizes == 0)
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from .lifeline import lifeline_buddies, match_steals
from .params import GLBParams
from .problem import GLBProblem
from .stats import init_stats, update_stats


class GLBRun(NamedTuple):
    result: Any                   # reduced result (the paper's `reduce()`)
    per_place: Any                # per-place results, leading P axis
    stats: Dict[str, jax.Array]   # per-place counters, leading P axis
    supersteps: jax.Array         # i32
    converged: jax.Array          # bool — False only if max_supersteps hit


def _select(cond_p: jax.Array, a: Any, b: Any) -> Any:
    """Per-place select over pytrees with leading P axis."""
    def sel(x, y):
        c = cond_p.reshape(cond_p.shape + (1,) * (x.ndim - 1))
        return jnp.where(c, x, y)
    return jax.tree.map(sel, a, b)


def reduce_result(per_place: Any, op: str) -> Any:
    if op == "sum":
        return jax.tree.map(lambda x: x.sum(axis=0), per_place)
    if op == "max":
        return jax.tree.map(lambda x: x.max(axis=0), per_place)
    if op == "min":
        return jax.tree.map(lambda x: x.min(axis=0), per_place)
    raise ValueError(f"unknown reduce op {op!r}")


def run_sim(
    problem: GLBProblem,
    P: int,
    params: GLBParams = GLBParams(),
    seed: int = 0,
    max_supersteps: Optional[int] = None,
    tracer=None,
) -> GLBRun:
    """Execute `problem` on P simulated places. Fully jit-compiled.

    With an enabled ``tracer`` (``repro.obs.Tracer``), the SAME jitted
    superstep body runs under a host loop instead of ``lax.while_loop``,
    emitting one ``superstep`` span and a ``glb_load`` counter per
    iteration (one device->host sync each — the traced path trades a
    sync per superstep for the timeline; results are numerically
    identical, asserted in ``tests/test_obs.py``)."""
    z = params.resolve_z(P)
    buddies = jnp.asarray(lifeline_buddies(P, z))
    max_steps = max_supersteps or params.max_supersteps

    vprocess = jax.vmap(problem.process, in_axes=(0, 0, None))
    vsplit = jax.vmap(problem.split, in_axes=(0, None))
    vmerge = jax.vmap(problem.merge)

    def init_carry():
        states, bags = jax.vmap(lambda p: problem.init_place(p, P))(
            jnp.arange(P, dtype=jnp.int32)
        )
        return dict(
            states=states,
            bags=bags,
            pending=jnp.zeros((P, P), bool),
            step=jnp.zeros((), jnp.int32),
            done=jnp.zeros((), bool),
            stats=init_stats(P),
        )

    def body(c, key):
        # 1. process
        states, bags, processed = vprocess(c["states"], c["bags"], params.n)
        sizes = bags["size"]
        # In-progress, non-stealable work held in state (paper §2.6's
        # interruptable state machine) counts for hunger/termination.
        if problem.work_in_state is not None:
            pend = jax.vmap(problem.work_in_state)(states).astype(jnp.int32)
        else:
            pend = jnp.zeros_like(sizes)
        hungry = (sizes + pend) == 0

        # 2-3. match thieves to victims (replicated-deterministic)
        k_step = jax.random.fold_in(key, c["step"])
        m = match_steals(sizes, hungry, c["pending"], k_step, buddies, params)

        # 4. transfer: victims split, packets routed, thieves merge
        bags_split, packets = vsplit(bags, params.steal_k)
        give = m.dst >= 0
        packets["count"] = jnp.where(give, packets["count"], 0)
        bags = _select(give, bags_split, bags)

        srcc = jnp.clip(m.src, 0, P - 1)
        recv = jax.tree.map(lambda x: x[srcc], packets)
        recv["count"] = jnp.where(m.src >= 0, recv["count"], 0)
        bags = vmerge(bags, recv)

        # 5. termination: if no work existed post-process, none was
        # transferred either (victims need size>0), so this is exact.
        done = (sizes.sum() + pend.sum()) == 0

        stats = update_stats(
            c["stats"],
            processed=processed,
            hungry=hungry,
            src=m.src,
            via_lifeline=m.via_lifeline,
            dst=m.dst,
            sent=packets["count"],
            recv=recv["count"],
            registered=(m.pending & ~c["pending"]).any(axis=1),
            sizes=bags["size"],
        )
        return dict(
            states=states,
            bags=bags,
            pending=m.pending,
            step=c["step"] + 1,
            done=done,
            stats=stats,
        )

    def finish(out) -> GLBRun:
        per_place = jax.vmap(problem.result)(out["states"])
        result = reduce_result(per_place, problem.reduce_op)
        return GLBRun(
            result=result,
            per_place=per_place,
            stats=out["stats"],
            supersteps=out["step"],
            converged=out["done"],
        )

    if tracer is None or not getattr(tracer, "enabled", False):
        def _run(key):
            def cond(c):
                return (~c["done"]) & (c["step"] < max_steps)

            out = jax.lax.while_loop(cond, lambda c: body(c, key),
                                     init_carry())
            return finish(out)

        return jax.jit(_run)(jax.random.key(seed))

    # Traced path: host loop around the SAME jitted body — identical key
    # folding and superstep recurrence, so results match the jitted
    # while_loop bit-for-bit; the loop condition mirrors ``cond`` above.
    tracer.process_name(0, f"GLB sim ({P} places)")
    tracer.thread_name(0, 0, "supersteps")
    step_fn = jax.jit(body)
    key = jax.random.key(seed)
    carry = jax.jit(init_carry)()
    while (not bool(carry["done"])) and int(carry["step"]) < max_steps:
        with tracer.span("superstep", pid=0,
                         args={"n": int(carry["step"])}):
            carry = step_fn(carry, key)
            sizes = jax.device_get(carry["bags"]["size"])
            vals = {"total": float(sizes.sum()),
                    "hungry": float((sizes == 0).sum())}
            if P <= 16:
                vals.update({f"place{i}": float(v)
                             for i, v in enumerate(sizes)})
            tracer.counter("glb_load", vals, pid=0)
    return jax.jit(finish)(carry)
