"""GLB logging counters (paper §2.4).

The paper logs, per worker: (1) time spent processing vs distributing work,
(2) random/lifeline steal requests sent and received, (3) steals perpetrated,
(4) workload sent/received. In the bulk-synchronous adaptation "time" becomes
superstep counts; everything else maps 1:1.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

FIELDS = (
    "processed",        # work units processed (paper: tasks computed)
    "active_steps",     # supersteps in which this place processed > 0 items
    "idle_steps",       # supersteps in which this place was hungry
    "steals_random",    # successful steals via the random round (as thief)
    "steals_lifeline",  # successful steals via a lifeline edge (as thief)
    "served",           # steals served (as victim, "perpetrated" on us)
    "items_sent",       # task items shipped out
    "items_recv",       # task items received
    "lifeline_regs",    # lifeline registrations (requests "sent")
    "max_size",         # high-water mark of the bag (capacity audit)
)


def init_stats(P: int) -> Dict[str, jax.Array]:
    return {f: jnp.zeros((P,), jnp.int32) for f in FIELDS}


def update_stats(
    stats: Dict[str, jax.Array],
    *,
    processed: jax.Array,      # (P,) items processed this superstep
    hungry: jax.Array,         # (P,) bool at match time
    src: jax.Array,            # (P,) victim index or -1
    via_lifeline: jax.Array,   # (P,) bool
    dst: jax.Array,            # (P,) thief index or -1
    sent: jax.Array,           # (P,) packet items sent
    recv: jax.Array,           # (P,) packet items received
    registered: jax.Array,     # (P,) bool — registered lifelines this step
    sizes: jax.Array,          # (P,) post-transfer bag sizes
) -> Dict[str, jax.Array]:
    got = src >= 0
    s = dict(stats)
    s["processed"] = stats["processed"] + processed.astype(jnp.int32)
    s["active_steps"] = stats["active_steps"] + (processed > 0)
    s["idle_steps"] = stats["idle_steps"] + hungry
    s["steals_random"] = stats["steals_random"] + (got & ~via_lifeline)
    s["steals_lifeline"] = stats["steals_lifeline"] + (got & via_lifeline)
    s["served"] = stats["served"] + (dst >= 0)
    s["items_sent"] = stats["items_sent"] + sent.astype(jnp.int32)
    s["items_recv"] = stats["items_recv"] + recv.astype(jnp.int32)
    s["lifeline_regs"] = stats["lifeline_regs"] + registered
    s["max_size"] = jnp.maximum(stats["max_size"], sizes.astype(jnp.int32))
    return s


def merge_place_stats(per_place) -> Dict[str, Dict[str, float]]:
    """Result collection (paper §2.4): reduce a list of per-place stat
    dicts — GLB places or serving replicas, any numeric fields — into one
    fabric-level report of total/mean/max(+argmax) per field. Fields are
    the union across places (a replica without a prefix cache simply
    contributes 0), so heterogeneous fabrics still merge."""
    fields: list = []
    for st in per_place:
        fields.extend(f for f in st if f not in fields)
    out: Dict[str, Dict[str, float]] = {}
    for f in fields:
        v = np.asarray([float(st.get(f, 0)) for st in per_place])
        out[f] = {
            "total": float(v.sum()),
            "mean": float(v.mean()),
            "max": float(v.max()),
            "argmax": int(v.argmax()),
        }
    return out


def fabric_summary(per_place, title: str = "fabric",
                   places: int = None) -> str:
    """Human-readable merged report, one line per field — the serving
    analogue of ``summarize`` (which formats the executor's device-array
    stats). Includes the paper's imbalance metric over whichever field
    carries the work count (``processed`` or ``tokens_out``).

    Accepts either a list of per-place stat dicts (merged here) or an
    ALREADY-merged mapping ``field -> {total, mean, max, argmax}`` such
    as the replica balancer's ``collect()`` — underscore-prefixed
    sub-reports (``"_balancer"``) are skipped, and ``places`` names the
    place count the merge no longer carries."""
    if isinstance(per_place, dict):
        merged = {f: m for f, m in per_place.items()
                  if isinstance(m, dict) and not f.startswith("_")}
        P = places if places is not None else 1 + max(
            (int(m.get("argmax", 0)) for m in merged.values()), default=0
        )
    else:
        merged = merge_place_stats(per_place)
        P = len(per_place)
    lines = [f"{title}: {P} places"]
    for f, m in merged.items():
        lines.append(
            f"  {f:<18} total={m['total']:>12.0f}  mean={m['mean']:>10.1f}"
            f"  max={m['max']:>10.0f} (place {m['argmax']})"
        )
    for work in ("processed", "tokens_out"):
        if work in merged and merged[work]["total"] > 0:
            m = merged[work]
            lines.append(
                f"  workload imbalance: max/mean="
                f"{m['max'] / max(m['mean'], 1e-9):.3f}"
            )
            break
    return "\n".join(lines)


def summarize(stats: Dict[str, np.ndarray], supersteps: int) -> str:
    """Paper-style log summary across places."""
    st = {k: np.asarray(v) for k, v in stats.items()}
    P = st["processed"].shape[0]
    lines = [f"GLB stats over {P} places, {supersteps} supersteps"]
    for f in FIELDS:
        v = st[f]
        lines.append(
            f"  {f:<16} total={int(v.sum()):>12}  mean={v.mean():>12.1f}  "
            f"std={v.std():>10.2f}  max={int(v.max()):>10}"
        )
    proc = st["processed"].astype(np.float64)
    if proc.sum() > 0:
        # Workload-distribution quality, the paper's Fig. 6/8/10 metric.
        lines.append(
            f"  workload imbalance: max/mean={proc.max() / max(proc.mean(), 1e-9):.3f}"
            f"  std/mean={proc.std() / max(proc.mean(), 1e-9):.3f}"
        )
    return "\n".join(lines)
