"""Array-backed TaskBag — the paper's default ``ArrayList``-based bag (§2.3).

A bag is a pytree::

    {"items": {field: (C, *trailing) array, ...}, "size": i32 scalar}

with a *static* capacity ``C``. All operations are pure jnp functions so they
work identically under ``vmap`` (simulated places on one device) and inside
``shard_map`` (one bag per TPU device).

The paper's default split "removes half of the elements from the end of the
ArrayList"; ``split_tail_half`` implements exactly that (capped at the steal
packet size K). Problem-specific bags (UTS, BC) override split with the
paper's interval-halving scheme instead (§2.5.2, §2.6.2) — those live in
``repro.problems``.

Capacity discipline: callers must keep ``size + K <= C`` before a merge; the
constructors over-allocate a ``K`` slack region so the paper-level capacity is
honoured. Writes beyond ``size`` are dead space and may hold garbage.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

Bag = Dict[str, Any]     # {"items": {...}, "size": i32}
Packet = Dict[str, Any]  # {"items": {...(K, ...)}, "count": i32}


def make_bag(item_spec: Dict[str, jax.ShapeDtypeStruct], capacity: int) -> Bag:
    """An empty bag with room for `capacity` items (plus internal slack)."""
    items = {
        k: jnp.zeros((capacity,) + tuple(s.shape), s.dtype)
        for k, s in item_spec.items()
    }
    return {"items": items, "size": jnp.zeros((), jnp.int32)}


def make_packet(item_spec: Dict[str, jax.ShapeDtypeStruct], k: int) -> Packet:
    items = {
        key: jnp.zeros((k,) + tuple(s.shape), s.dtype)
        for key, s in item_spec.items()
    }
    return {"items": items, "count": jnp.zeros((), jnp.int32)}


def bag_size(bag: Bag) -> jax.Array:
    return bag["size"]


def _update_block(arr: jax.Array, block: jax.Array, start: jax.Array) -> jax.Array:
    """dynamic_update_slice of `block` rows at row offset `start`."""
    zeros = (jnp.zeros((), jnp.int32),) * (arr.ndim - 1)
    return jax.lax.dynamic_update_slice(arr, block.astype(arr.dtype), (start,) + zeros)


def push_block(bag: Bag, block: Dict[str, jax.Array], count: jax.Array) -> Bag:
    """Append `count` valid rows of `block` (leading K axis). Rows beyond
    `count` are written into dead space and overwritten by later pushes.

    The write is guarded on ``count > 0``: dynamic_update_slice clamps its
    start offset, so an unguarded no-op push into a nearly-full bag would
    otherwise overwrite live rows (merges are broadcast to all places with
    count 0 almost everywhere)."""
    size = bag["size"]
    count = count.astype(jnp.int32)
    items = {}
    for k, v in bag["items"].items():
        written = _update_block(v, block[k], size)
        items[k] = jnp.where(count > 0, written, v)
    return {"items": items, "size": size + count}


def push_one(bag: Bag, item: Dict[str, jax.Array]) -> Bag:
    block = {k: v[None] for k, v in item.items()}
    return push_block(bag, block, jnp.int32(1))


def peek_tail(bag: Bag) -> Dict[str, jax.Array]:
    idx = jnp.maximum(bag["size"] - 1, 0)
    return {k: v[idx] for k, v in bag["items"].items()}


def pop_tail(bag: Bag) -> tuple[Bag, Dict[str, jax.Array]]:
    item = peek_tail(bag)
    return {"items": bag["items"], "size": jnp.maximum(bag["size"] - 1, 0)}, item


def read_front(bag: Bag, k: int) -> Dict[str, jax.Array]:
    """First (oldest) k rows — static slice."""
    return {key: v[:k] for key, v in bag["items"].items()}


def write_front(bag: Bag, block: Dict[str, jax.Array]) -> Bag:
    items = {k: _update_block(v, block[k], jnp.int32(0)) for k, v in bag["items"].items()}
    return {"items": items, "size": bag["size"]}


def split_tail_half(bag: Bag, k: int) -> tuple[Bag, Packet]:
    """Paper's default ArrayList split: remove ceil(half) of the elements from
    the END of the list (capped at the packet width k) and hand them over."""
    size = bag["size"]
    take = jnp.minimum((size + 1) // 2, k)
    start = jnp.maximum(size - take, 0)
    zerotails = lambda a: (jnp.zeros((), jnp.int32),) * (a.ndim - 1)
    pkt_items = {
        key: jax.lax.dynamic_slice(v, (start,) + zerotails(v), (k,) + v.shape[1:])
        for key, v in bag["items"].items()
    }
    # Rows beyond `take` in the packet are garbage; mask them out so the
    # packet is self-describing (and zeroed rows compress well on the wire).
    lane = jnp.arange(k)
    pkt_items = {
        key: jnp.where(
            (lane < take).reshape((k,) + (1,) * (v.ndim - 1)), v, jnp.zeros_like(v)
        )
        for key, v in pkt_items.items()
    }
    new_bag = {"items": bag["items"], "size": size - take}
    return new_bag, {"items": pkt_items, "count": take.astype(jnp.int32)}


def merge_packet(bag: Bag, packet: Packet) -> Bag:
    """Paper's default merge: append the incoming items (§2.3)."""
    return push_block(bag, packet["items"], packet["count"])


def compact_block(block: Dict[str, jax.Array], valid: jax.Array) -> tuple[Dict[str, jax.Array], jax.Array]:
    """Stable-compact valid rows of a (K, ...) block to the front.

    Returns (compacted block, count). Invalid rows are zeroed.
    """
    k = valid.shape[0]
    order = jnp.argsort(~valid, stable=True)  # valid lanes first, stable
    count = valid.sum().astype(jnp.int32)
    lane = jnp.arange(k)
    out = {}
    for key, v in block.items():
        g = v[order]
        mask = (lane < count).reshape((k,) + (1,) * (v.ndim - 1))
        out[key] = jnp.where(mask, g, jnp.zeros_like(g))
    return out, count


def empty_like_packet(packet: Packet) -> Packet:
    return {
        "items": {k: jnp.zeros_like(v) for k, v in packet["items"].items()},
        "count": jnp.zeros((), jnp.int32),
    }
