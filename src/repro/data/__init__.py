"""Deterministic, checkpointable synthetic data pipeline."""
