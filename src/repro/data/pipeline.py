"""Deterministic, checkpointable synthetic token pipeline.

Production-shaped: the iterator's full state is (seed, step), so a restore
replays the exact same batches (resume-determinism is tested); batches are
sharded per DP rank by slicing the global batch. A "document length"
distribution creates the packing irregularity the GLB balancer cares about.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass
class DataState:
    seed: int
    step: int

    def to_dict(self):
        return {"seed": self.seed, "step": self.step}

    @staticmethod
    def from_dict(d):
        return DataState(int(d["seed"]), int(d["step"]))


class SyntheticTokens:
    """Zipf-ish token stream with geometric document lengths, packed into
    fixed (B, S) batches with EOS separators. Deterministic in (seed, step).
    """

    def __init__(self, cfg: ModelConfig, batch: int, seq: int, seed: int = 0,
                 eos: int = 1):
        self.cfg = cfg
        self.batch = batch
        self.seq = seq
        self.eos = eos
        self.state = DataState(seed=seed, step=0)

    def _gen(self, rng: np.random.Generator, n: int) -> np.ndarray:
        # zipf-flavored unigram stream, clipped to vocab
        v = self.cfg.vocab
        z = rng.zipf(1.3, size=n).astype(np.int64)
        return np.minimum(z + 1, v - 1)

    def next_batch(self) -> Dict[str, np.ndarray]:
        st = self.state
        rng = np.random.default_rng(
            np.random.SeedSequence(entropy=st.seed, spawn_key=(st.step,))
        )
        B, S = self.batch, self.seq
        toks = self._gen(rng, B * S).reshape(B, S)
        # sprinkle document boundaries (geometric lengths, mean S/4)
        for b in range(B):
            pos = 0
            while pos < S:
                ln = int(rng.geometric(4.0 / S)) + 1
                pos += ln
                if pos < S:
                    toks[b, pos] = self.eos
        self.state = DataState(st.seed, st.step + 1)
        out: Dict[str, np.ndarray] = {}
        if self.cfg.n_codebooks:
            q = np.stack(
                [(toks * (k + 3)) % self.cfg.vocab
                 for k in range(self.cfg.n_codebooks)], axis=-1
            )
            out["tokens"] = q.astype(np.int32)
        elif self.cfg.family == "vlm":
            d = self.cfg.d_model
            out["embeds"] = rng.standard_normal((B, S, d)).astype(np.float32)
            pos3 = np.broadcast_to(np.arange(S, dtype=np.int32)[None, :, None],
                                   (B, S, 3)).copy()
            out["positions"] = pos3
            out["labels"] = toks.astype(np.int32)
        else:
            out["tokens"] = toks.astype(np.int32)
        return out

    def shard(self, batch: Dict[str, np.ndarray], rank: int, world: int):
        per = self.batch // world
        return {k: v[rank * per:(rank + 1) * per] for k, v in batch.items()}
