"""repro.dist — the static-partitioning half of the GLB thesis (DESIGN.md §5).

The lifeline work-stealer (repro.core) balances *dynamic* workloads at run
time; this package is its static counterpart: it decides, before the program
runs, how every model / optimizer / cache / activation tensor is laid out
over the mesh the GLB executor runs on.

  sharding : logical-axis rule engine — params, inputs, caches and
             activations name *logical* axes ("embed", "qkv", "batch", ...)
             and the engine resolves them to mesh PartitionSpecs with
             divisibility fallback and per-tensor conflict resolution.
  compress : int8 error-feedback gradient compression for the multi-pod
             DCN-crossing data-parallel sync.
  pipeline : microbatched GPipe-style pipeline parallelism over a `stage`
             mesh axis.
"""
from .compress import compressed_psum_mean, init_error, quantize_roundtrip
from .pipeline import pipeline_forward, split_layers_into_stages
from .sharding import (
    batch_axes,
    cache_axes,
    opt_axes,
    param_axes,
    shard_act,
    spec_for,
    tree_shardings,
    tree_specs,
)

__all__ = [
    "batch_axes", "cache_axes", "opt_axes", "param_axes", "shard_act",
    "spec_for", "tree_shardings", "tree_specs",
    "compressed_psum_mean", "init_error", "quantize_roundtrip",
    "pipeline_forward", "split_layers_into_stages",
]
