"""Int8 error-feedback gradient compression (DESIGN.md §5).

The multi-pod mesh crosses DCN on the leading `pod` axis, where the
all-reduce of float32 gradients is the scaling bottleneck. This module
implements the standard EF-SGD compressed all-reduce:

  corrected = grad + err            # fold in what previous rounds dropped
  q, scale  = int8_quantize(corrected)   # shared scale across the axis
  out       = psum(q) * scale / n   # int8 on the wire, 4x fewer DCN bytes
  err'      = corrected - q * scale # remember this round's truncation

Error feedback keeps the *time-averaged* transmitted gradient unbiased, so
training tracks the exact-psum run closely (test_compress_dp.py) even
though each round only ships 8-bit values.

The quantization scale is shared across the reduction axis (``pmax`` of the
per-device amax), which is what makes summing raw int8 payloads valid —
each device contributes q_i on the same grid, and a single int32 psum plus
one scalar multiply reconstructs the mean.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

_QMAX = 127.0


def init_error(tree: Any) -> Any:
    """Zero-initialised persistent error-feedback buffers, float32, one per
    gradient leaf. Thread these through training steps."""
    return jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), tree)


def _quantize(x: jax.Array, amax: jax.Array):
    scale = jnp.maximum(amax, 1e-30) / _QMAX
    q = jnp.clip(jnp.round(x / scale), -_QMAX, _QMAX).astype(jnp.int8)
    return q, scale


def quantize_roundtrip(x: jax.Array) -> jax.Array:
    """Symmetric per-tensor int8 quantize -> dequantize. Worst-case error is
    half a quantization step, i.e. <= amax / 127."""
    xf = x.astype(jnp.float32)
    q, scale = _quantize(xf, jnp.max(jnp.abs(xf)))
    return (q.astype(jnp.float32) * scale).astype(x.dtype)


def compressed_psum_mean(
    tree: Any, axis: Optional[str], err: Any
) -> Tuple[Any, Any]:
    """Compressed mean-all-reduce of ``tree`` over mesh axis ``axis`` with
    persistent error feedback ``err`` (from :func:`init_error`).

    Inside ``shard_map`` pass the mesh axis name; with ``axis=None`` the
    collective degenerates to a local quantize-roundtrip (the single-device
    / unit-test path). Returns ``(mean_tree, new_err)``.
    """
    def leaf(g, e):
        corrected = g.astype(jnp.float32) + e
        amax = jnp.max(jnp.abs(corrected))
        if axis is not None:
            # shared grid across the axis so raw int8 payloads sum exactly
            amax = lax.pmax(amax, axis)
        q, scale = _quantize(corrected, amax)
        sent = q.astype(jnp.float32) * scale
        if axis is None:
            out = sent
        else:
            n = lax.axis_size(axis)
            out = lax.psum(q.astype(jnp.int32), axis).astype(jnp.float32) * (
                scale / n
            )
        return out.astype(g.dtype), corrected - sent

    flat_g, treedef = jax.tree.flatten(tree)
    if jax.tree.structure(err) != treedef:
        raise ValueError(
            f"error-feedback tree structure {jax.tree.structure(err)} does "
            f"not match gradient tree {treedef}; build it with init_error()"
        )
    flat_e = jax.tree.leaves(err)
    pairs = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    out = jax.tree.unflatten(treedef, [p[0] for p in pairs])
    new_err = jax.tree.unflatten(treedef, [p[1] for p in pairs])
    return out, new_err
