"""GPipe-style pipeline parallelism over a `stage` mesh axis (DESIGN.md §5).

``split_layers_into_stages`` reshapes a scanned layer stack (leading layer
dim) into ``n_stages`` contiguous chunks; ``pipeline_forward`` runs the
classic microbatched fill-drain schedule inside one ``shard_map``:

  tick t: stage 0 injects microbatch t (while t < M), every stage applies
  its chunk to whatever it holds, and a ``ppermute`` shifts activations one
  stage rightward. After M + S - 1 ticks every microbatch has crossed all
  S stages; the last stage accumulates outputs, which a masked psum
  replicates outward.

Ticks where a stage holds no live microbatch (pipeline bubbles) run the
stage on zeros and the result is simply never collected.

Each device holds only its own 1/S slice of the layer weights and carries
one live microbatch activation through the loop; the (M, ...) microbatch
input stack and output buffer, however, are replicated to every stage
(in_specs P() / final psum), so per-device *buffer* memory is O(M). That
is fine at the microbatch counts the schedule targets (M ~ a few x S); a
streaming variant that feeds stage 0 only and gathers from the last stage
would bring buffers to O(M/S) at the cost of a more complex collective
pattern.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P


def split_layers_into_stages(params: Any, n_stages: int) -> Any:
    """Reshape each leaf's leading layer dim L -> (n_stages, L // n_stages).

    The result is fed to :func:`pipeline_forward`, whose shard_map splits
    the leading stage dim over the `stage` mesh axis."""
    def split(a):
        L = a.shape[0]
        if L % n_stages != 0:
            raise ValueError(
                f"layer count {L} not divisible into {n_stages} stages"
            )
        return a.reshape((n_stages, L // n_stages) + a.shape[1:])

    return jax.tree.map(split, params)


def pipeline_forward(
    fn: Callable[[Any, jax.Array], jax.Array],
    stages: Any,
    x: jax.Array,
    mesh,
    axis: str = "stage",
) -> jax.Array:
    """Run ``fn(stage_params, h)`` as an S-stage pipeline over microbatches.

    ``stages``: pytree from :func:`split_layers_into_stages` (leading dim =
    number of stages). ``x``: (M, microbatch..., ) stacked microbatch inputs.
    ``fn`` must preserve the shape/dtype of its activation argument.
    Returns the (M, ...) outputs, bit-matching the sequential schedule.
    """
    S = int(mesh.shape[axis])
    lead = {int(leaf.shape[0]) for leaf in jax.tree.leaves(stages)}
    if lead != {S}:
        raise ValueError(
            f"stage count {lead} != mesh axis {axis!r} size {S}"
        )
    M = x.shape[0]
    n_ticks = M + S - 1

    def per_stage(sp, xall):
        sp = jax.tree.map(lambda a: a[0], sp)   # drop the sharded stage dim
        idx = lax.axis_index(axis)
        last = S - 1
        state = jnp.zeros_like(xall[0])
        buf = jnp.zeros_like(xall)

        def tick(t, carry):
            state, buf = carry
            # stage 0 injects microbatch t; others consume last tick's recv
            feed = lax.dynamic_index_in_dim(
                xall, jnp.clip(t, 0, M - 1), 0, keepdims=False
            )
            h = jnp.where(idx == 0, feed, state)
            out = fn(sp, h)
            # the last stage finishes microbatch (t - last) on this tick
            slot = t - last
            collected = lax.dynamic_update_index_in_dim(
                buf, out, jnp.clip(slot, 0, M - 1), 0
            )
            take = (idx == last) & (slot >= 0) & (slot < M)
            buf = jnp.where(take, collected, buf)
            # shift activations one stage rightward; stage 0 receives zeros
            state = lax.ppermute(
                out, axis, [(i, i + 1) for i in range(S - 1)]
            )
            return state, buf

        _, buf = lax.fori_loop(0, n_ticks, tick, (state, buf))
        # only the last stage holds real outputs -> masked psum replicates
        buf = jnp.where(idx == last, buf, jnp.zeros_like(buf))
        return lax.psum(buf, axis)

    shmapped = jax.shard_map(
        per_stage, mesh=mesh,
        in_specs=(P(axis), P()), out_specs=P(),
        check_vma=False,
    )
    return shmapped(stages, x)
