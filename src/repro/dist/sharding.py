"""Logical-axis sharding rule engine (DESIGN.md §5).

Every tensor in the system — param leaves, optimizer state, inputs, KV /
SSM caches, activations — is described by a tuple of *logical* axis names
("embed", "qkv", "batch", "cache_seq", ...). This module owns the single
table that maps logical names to mesh axes and resolves any (shape,
logical-axes, mesh) triple into a concrete ``PartitionSpec``:

  * divisibility fallback — a mesh axis that does not divide the dimension
    is dropped (replicate rather than produce an uneven GSPMD split);
  * multi-axis batch — "batch" maps to ``("pod", "data")`` so the same rule
    covers single-pod (data only) and multi-pod (DP over DCN) meshes, taking
    every dividing axis in rule order (a non-dividing axis is skipped, later
    candidates are still tried);
  * per-tensor conflict resolution — a mesh axis is consumed at most once
    per spec, first (leftmost) logical axis wins, later claimants replicate.

The layout this encodes is FSDP("data") x TP/EP("model") x DP("pod","data"):
weight embed dims shard over `data` (ZeRO-3 style), head/ffn/expert/vocab
dims over `model`, batch dims over (`pod`, `data`), and decode KV caches
spread their sequence dim over `model`.

Only ``mesh.shape`` (a name->size mapping) is consulted, so tests can pass
lightweight fakes; ``tree_shardings`` needs a real device mesh.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.tree_util as jtu
from jax.sharding import NamedSharding, PartitionSpec as P

Axes = Tuple[str, ...]

# Logical axis -> ordered mesh-axis candidates. An empty tuple means
# "always replicated".
LOGICAL_AXIS_RULES: Dict[str, Tuple[str, ...]] = {
    # data-ish dims
    "batch": ("pod", "data"),
    "seq": (),
    "act_embed": (),
    # weight dims
    "embed": ("data",),          # FSDP / ZeRO-3: weight embed dim over data
    "vocab": ("model",),
    "qkv": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "ffn": ("model",),
    "expert": ("model",),        # expert parallelism
    "d_inner": ("model",),       # mamba inner channels (TP)
    # cache dims
    "cache_seq": ("model",),     # decode KV cache: sequence over model
    # structural / replicated
    "layer": (),
    "conv": (),
    "state": (),
    "none": (),
}


def spec_for(shape: Tuple[int, ...], axes: Axes, mesh) -> P:
    """Resolve logical ``axes`` for a tensor of ``shape`` on ``mesh``.

    ``mesh`` needs only a ``.shape`` mapping of axis name -> size.
    """
    if len(shape) != len(axes):
        raise ValueError(
            f"rank mismatch: shape {shape} vs logical axes {axes}"
        )
    mesh_shape = dict(mesh.shape)
    used: set = set()
    entries = []
    for dim, name in zip(shape, axes):
        try:
            rule = LOGICAL_AXIS_RULES[name]
        except KeyError:
            raise KeyError(
                f"unknown logical axis {name!r}; known: "
                f"{sorted(LOGICAL_AXIS_RULES)}"
            ) from None
        picked = []
        rem = int(dim)
        for ax in rule:
            n = mesh_shape.get(ax)
            if n is None or ax in used:
                continue
            if rem % n == 0:
                picked.append(ax)
                used.add(ax)
                rem //= n
        if not picked:
            entries.append(None)
        elif len(picked) == 1:
            entries.append(picked[0])
        else:
            entries.append(tuple(picked))
    return P(*entries)


def _is_axes_leaf(x) -> bool:
    return isinstance(x, tuple) and all(isinstance(e, str) for e in x)


def tree_specs(axes_tree, shapes_tree, mesh):
    """Map a logical-axes tree + matching shape tree -> PartitionSpec tree."""
    return jax.tree.map(
        lambda ax, s: spec_for(tuple(s.shape), ax, mesh),
        axes_tree, shapes_tree, is_leaf=_is_axes_leaf,
    )


def tree_shardings(axes_tree, shapes_tree, mesh):
    """Like ``tree_specs`` but wraps each spec in a NamedSharding (real
    device mesh required) — the form jit in_shardings/out_shardings take."""
    specs = tree_specs(axes_tree, shapes_tree, mesh)
    return jax.tree.map(
        lambda sp: NamedSharding(mesh, sp), specs,
        is_leaf=lambda x: isinstance(x, P),
    )


# --------------------------------------------------------------- params
_ATTN_AXES = {
    "wq": ("embed", "qkv"), "wk": ("embed", "qkv"), "wv": ("embed", "qkv"),
    "wo": ("qkv", "embed"),
    "bq": ("qkv",), "bk": ("qkv",), "bv": ("qkv",),
}
_MLP_AXES = {
    "wg": ("embed", "ffn"), "wi": ("embed", "ffn"), "wo": ("ffn", "embed"),
}
_MOE_AXES = {
    "router": ("embed", "none"),
    # pure EP: `model` is consumed by the expert dim, so the ffn dim
    # conflict-resolves to replicated within each expert shard
    "wg": ("expert", "embed", "ffn"),
    "wi": ("expert", "embed", "ffn"),
    "wo": ("expert", "ffn", "embed"),
}
_MAMBA_AXES = {
    "wz": ("embed", "d_inner"), "wx": ("embed", "d_inner"),
    "wB": ("embed", "none"), "wC": ("embed", "none"),
    "wdt": ("embed", "none"),
    "conv_x": ("conv", "d_inner"),
    "conv_B": ("conv", "none"), "conv_C": ("conv", "none"),
    "A_log": ("none",), "D": ("none",), "dt_bias": ("none",),
    "gate_norm": ("d_inner",),
    "out_proj": ("d_inner", "embed"),
}
_BY_PARENT = {
    "attn": _ATTN_AXES, "mlp": _MLP_AXES, "moe": _MOE_AXES,
    "mamba": _MAMBA_AXES,
}
_NORMS = {"ln", "ln1", "ln2", "final_ln"}


def _key_name(entry) -> str:
    return str(getattr(entry, "key", getattr(entry, "name", entry)))


def _param_leaf_axes(path, ndim: int) -> Axes:
    keys = [_key_name(k) for k in path]
    name = keys[-1]
    parent = keys[-2] if len(keys) >= 2 else None
    stacked = keys[0] == "blocks"  # vmapped layer stack: leading layer dim

    if name == "embed":
        base = ("vocab", "embed") if ndim == 2 else ("none", "vocab", "embed")
    elif name == "head":
        base = ("embed", "vocab") if ndim == 2 else ("none", "embed", "vocab")
    elif name in _NORMS:
        base = ("embed",)
    elif parent in _BY_PARENT and name in _BY_PARENT[parent]:
        base = _BY_PARENT[parent][name]
    else:
        raise KeyError(
            f"no logical-axis rule for param leaf {'/'.join(keys)!r}"
        )
    axes = (("layer",) + base) if stacked else base
    if len(axes) != ndim:
        raise ValueError(
            f"param leaf {'/'.join(keys)!r}: rank {ndim} != axes {axes}"
        )
    return axes


def param_axes(cfg, pshapes=None) -> Any:
    """Logical-axes pytree matching ``init_lm(key, cfg)`` for any registered
    arch (dense / moe / ssm / hybrid / vlm / audio). Pass ``pshapes`` (an
    ``eval_shape`` of the init) when the caller already has it, to avoid
    re-tracing the full model init."""
    if pshapes is None:
        from repro.models import init_lm

        pshapes = jax.eval_shape(lambda: init_lm(jax.random.key(0), cfg))
    return jtu.tree_map_with_path(
        lambda path, leaf: _param_leaf_axes(path, leaf.ndim), pshapes
    )


def opt_axes(paxes) -> Any:
    """Axes for the AdamW state: moments mirror the params, step is scalar."""
    return {"m": paxes, "v": paxes, "step": ()}


# ---------------------------------------------------------------- inputs
def batch_axes(cfg, kind: str) -> Any:
    """Logical axes for ``configs.input_specs(cfg, shape)`` of each kind."""
    if kind in ("train", "prefill"):
        if cfg.family == "vlm":
            axes = {
                "embeds": ("batch", "seq", "act_embed"),
                "positions": ("batch", "seq", "none"),
            }
            if kind == "train":
                axes["labels"] = ("batch", "seq")
            return axes
        if cfg.n_codebooks:
            return {"tokens": ("batch", "seq", "none")}
        return {"tokens": ("batch", "seq")}
    # decode: one token against a cache
    tok = ("batch", "none", "none") if cfg.n_codebooks else ("batch", "none")
    return {"tokens": tok, "cache": cache_axes(cfg), "cache_len": ()}


def cache_axes(cfg) -> Any:
    """Logical axes for ``models.make_cache(cfg, ...)``. The KV sequence dim
    takes `model` (sequence-sharded decode cache), which conflict-resolves
    kv_heads to replicated."""
    from repro.models.transformer import n_attn_caches

    axes: Dict[str, Axes] = {}
    if n_attn_caches(cfg):
        kv = ("layer", "batch", "cache_seq", "kv_heads", "none")
        axes["k"] = kv
        axes["v"] = kv
    if cfg.family in ("ssm", "hybrid"):
        axes["conv_x"] = ("layer", "batch", "conv", "d_inner")
        axes["conv_B"] = ("layer", "batch", "conv", "none")
        axes["conv_C"] = ("layer", "batch", "conv", "none")
        axes["ssm"] = ("layer", "batch", "heads", "none", "none")
    return axes


# ----------------------------------------------------------- activations
_REAL_MESH_TYPES = tuple(
    t for t in (
        getattr(jax.sharding, "Mesh", None),
        getattr(jax.sharding, "AbstractMesh", None),
    ) if t is not None
)


def _ambient_mesh():
    try:
        m = jax.sharding.get_abstract_mesh()
    except Exception:  # noqa: BLE001 - no ambient-mesh API / no context
        return None
    if m is None or not getattr(m, "shape", None):
        return None
    return m


def shard_act(x, *axes: str):
    """``with_sharding_constraint`` resolved through the rule engine.

    Safely a no-op when called outside any mesh context (unit tests, eager
    CPU runs) — model code calls this unconditionally from scan bodies."""
    mesh = _ambient_mesh()
    if mesh is None:
        return x
    # a typo'd logical axis or rank mismatch is a caller bug and must raise,
    # not silently drop the constraint
    spec = spec_for(tuple(x.shape), axes, mesh)
    if not isinstance(mesh, _REAL_MESH_TYPES):
        return x  # test fakes: resolvable but not constrainable
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
