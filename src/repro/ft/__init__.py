"""Fault tolerance: checkpoint/restore (+async), elastic resharding."""
