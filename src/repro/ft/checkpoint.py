"""Checkpointing + elastic restore.

Layout (one directory per step)::

    <dir>/step_000123/
        manifest.json        # step, config name, tree structure, data state
        arrays/<flat_key>.npy

- save() device_gets the pytree (optionally on a background thread — the
  async path real clusters use so the TPUs keep stepping).
- restore() rebuilds the pytree and device_puts with the CALLER's shardings:
  the mesh at restore time may differ from save time (elastic rescale) —
  resharding is just a different device_put target.
- A `keep` window garbage-collects old steps.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import numpy as np
import jax


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: Dict[str, Any]):
    root: Dict[str, Any] = {}
    for key, v in flat.items():
        parts = key.split("/")
        d = root
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = v
    return root


def save(
    ckpt_dir: str,
    step: int,
    tree: Any,
    extra: Optional[Dict[str, Any]] = None,
    keep: int = 3,
    async_: bool = False,
):
    """Write checkpoint; with async_=True the file I/O happens on a
    background thread after a synchronous device_get snapshot."""
    flat = {k: np.asarray(jax.device_get(v)) for k, v in _flatten(tree).items()}

    def _write():
        d = os.path.join(ckpt_dir, f"step_{step:09d}")
        tmp = d + ".tmp"
        os.makedirs(os.path.join(tmp, "arrays"), exist_ok=True)
        for k, v in flat.items():
            np.save(os.path.join(tmp, "arrays", k.replace("/", "__") + ".npy"), v)
        manifest = {
            "step": step,
            "keys": sorted(flat),
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        if os.path.exists(d):
            shutil.rmtree(d)
        os.rename(tmp, d)
        _gc(ckpt_dir, keep)

    if async_:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(
        d for d in os.listdir(ckpt_dir) if d.startswith("step_")
    )
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    return steps[-1] if steps else None


def restore(
    ckpt_dir: str,
    step: Optional[int] = None,
    shardings: Any = None,
):
    """Load a checkpoint; device_put each leaf with the caller's shardings
    (None -> default placement). Returns (tree, manifest_extra, step)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:09d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    flat = {}
    for k in manifest["keys"]:
        flat[k] = np.load(
            os.path.join(d, "arrays", k.replace("/", "__") + ".npy")
        )
    tree = _unflatten(flat)
    if shardings is not None:
        flat_sh = _flatten(shardings)
        tree = _unflatten({
            k: jax.device_put(v, flat_sh[k]) if k in flat_sh else v
            for k, v in _flatten(tree).items()
        })
    return tree, manifest.get("extra", {}), step
