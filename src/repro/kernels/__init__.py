"""Pallas TPU kernels for the perf-critical compute layers, each with a
pure-jnp oracle in ref.py and a dispatching wrapper in ops.py:

  uts_expand.py      — the paper's UTS hot loop: batched node hashing +
                       geometric child counts (VPU integer mixing)
  flash_attention.py — causal GQA flash attention (online softmax, VMEM
                       scratch across the sequential kv grid dim, causal
                       block skip)
  flash_decode.py    — split-KV Sq==1 decode against a padded KV cache
                       (per-slot length masking, idle-slot/tail block
                       skip; the serving hot path)
  mamba2_ssd.py      — Mamba2 SSD chunk scan (matmul-form intra-chunk +
                       carried (N,P) state)

CPU container note: kernels are exercised with interpret=True in tests; the
models call ops.* which selects pallas on TPU and the oracle elsewhere.
"""
from . import ops, ref  # noqa: F401
