"""Pallas TPU flash attention (causal, GQA), VMEM-tiled with BlockSpecs.

Online-softmax blocked attention: grid = (batch, q_heads, q_blocks,
kv_blocks) with the kv dimension innermost — TPU grids execute sequentially,
so the running max / denominator / accumulator live in VMEM scratch across
kv steps and the output tile is written once on the last kv step.

Supports Sq != Skv with decode alignment (query i sits at absolute position
Skv - Sq + i), which is what the serving path needs (Sq == 1 against a long
KV cache), and GQA via the kv-head index map (h // group).

Oracle: ref.attention_ref. Validated in interpret mode on CPU; compiled on
TPU (MXU-aligned tiles: block_q/block_k multiples of 128 when shapes allow).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale, causal, sq, skv, block_q, block_k, num_kv):
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    i = pl.program_id(2)

    def _body():
        qpos = i * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0
        ) + (skv - sq)
        kpos = j * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1
        )
        q = q_ref[0, :, 0, :].astype(jnp.float32)           # (Bq, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)           # (Bk, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)           # (Bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                           # (Bq, Bk)
        if causal:
            mask = qpos >= kpos
            s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]                                 # (Bq, 1)
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev[:, 0], s.max(axis=-1))   # (Bq,)
        p = jnp.exp(s - m_new[:, None])
        if causal:
            p = jnp.where(qpos >= kpos, p, 0.0)
        alpha = jnp.exp(m_prev[:, 0] - m_new)               # (Bq,)
        l_new = alpha * l_prev[:, 0] + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new[:, None]
        l_ref[...] = l_new[:, None]

    if causal:
        # Block-level causal skip: a kv block whose first key position lies
        # strictly beyond this q block's last query is fully masked, so it
        # contributes nothing — pl.when drops its matmuls/iota entirely
        # (~2x fewer FLOPs on square causal prefill).
        q_max = i * block_q + block_q - 1 + (skv - sq)
        k_min = j * block_k
        pl.when(q_max >= k_min)(_body)
    else:
        _body()

    @pl.when(j == num_kv - 1)
    def _finish():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, :, 0, :] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q, k, v, *, causal: bool = True, scale: float | None = None,
    block_q: int = 128, block_k: int = 128, interpret: bool = False,
):
    """q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D) -> (B, Sq, Hq, D)."""
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    scale = float(scale) if scale is not None else 1.0 / np.sqrt(D)
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    assert Sq % bq == 0 and Skv % bk == 0, (Sq, bq, Skv, bk)
    num_kv = Skv // bk
    grid = (B, Hq, Sq // bq, num_kv)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, sq=Sq, skv=Skv,
        block_q=bq, block_k=bk, num_kv=num_kv,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, D), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, i, j: (b, j, h // group, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, i, j: (b, j, h // group, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, D), lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        # VMEM scratch carried across the sequential kv grid dimension.
        scratch_shapes=[
            _vmem((bq, D), jnp.float32),   # output accumulator
            _vmem((bq, 1), jnp.float32),   # running max
            _vmem((bq, 1), jnp.float32),   # running denominator
        ],
        interpret=interpret,
    )(q, k, v)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
