"""Split-KV Pallas flash-decode kernel: one query token against a padded
KV cache, specialized for the serving hot path (Sq == 1).

The prefill kernel (flash_attention.py) tiles queries and keys; at decode
there is exactly one query row per (batch, head), so the grid becomes
(batch, q_heads, kv_blocks) with the KV dimension innermost — TPU grids
execute sequentially, so the online-softmax partials (running max m,
denominator l, weighted accumulator acc) live in VMEM scratch across KV
steps and the (1, D) output tile is written once on the last step.

Per-slot cache lengths arrive as a scalar-prefetch operand
(PrefetchScalarGridSpec), so they gate the kernel at three levels:
  * DMA clamp   — the k/v index maps clamp past-window block indices to
    the slot's last live block; the pipeline sees an unchanged index and
    issues no new fetch, so a 70-token slot in a 4096-row bucket streams
    ~1/64th of the cache from HBM instead of all of it;
  * block skip  — ``pl.when`` drops the matmuls/softmax update for blocks
    at or past the window (idle slots, window == 0, skip every block and
    emit zeros);
  * lane mask   — the partial tail block masks key positions >= window
    before the softmax.

GQA rides on the kv-head index map (h // group), same as the prefill
kernel. Oracle: ref.attention_ref on the visible window (ref.decode_ref is
the padded-cache form). Validated in interpret mode on CPU; block sizes
for TPU come from core.autotune.DECODE_BLOCK_K.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref,
                   l_ref, *, scale, block_k, num_kv):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    n = len_ref[b]  # visible KV entries for this slot; 0 => idle

    @pl.when(j * block_k < n)  # skip past-window blocks and idle slots
    def _body():
        q = q_ref[0, 0, 0, :].astype(jnp.float32)[None, :]      # (1, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)               # (Bk, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)               # (Bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                               # (1, Bk)
        kpos = j * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_k), 1
        )
        live = kpos < n
        s = jnp.where(live, s, NEG_INF)
        m_prev = m_ref[...]                                     # (1, 1)
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(live, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)                         # (1, 1)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new
        l_ref[...] = alpha * l_prev + p.sum(axis=-1, keepdims=True)

    @pl.when(j == num_kv - 1)
    def _finish():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)  # idle slot: acc == 0 -> output 0
        o_ref[0, 0, 0, :] = (acc_ref[...] / l)[0].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("scale", "block_k", "interpret")
)
def flash_decode(q, k, v, lengths, *, scale: float | None = None,
                 block_k: int | None = None, interpret: bool = False):
    """q: (B, 1, Hq, D); k, v: (B, S, Hkv, D); lengths: (B,) i32 visible
    window per slot (0 => idle slot, output zeros). Returns (B, 1, Hq, D).
    """
    from jax.experimental.pallas import tpu as pltpu

    B, Sq, Hq, D = q.shape
    _, S, Hkv, _ = k.shape
    assert Sq == 1, f"flash_decode is Sq==1 only, got {Sq}"
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    scale = float(scale) if scale is not None else 1.0 / np.sqrt(D)
    if block_k is None:
        from repro.core.autotune import decode_block_k

        block_k = decode_block_k(S, D)
    bk = max(1, min(block_k, S))
    while S % bk:  # cache buckets are powers of two; keep the grid exact
        bk //= 2
    num_kv = S // bk
    lens = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32), (B,))

    def kv_map(b, h, j, lens):
        # Clamp past-window blocks to the slot's last live block: the
        # pipeline skips the DMA for a repeated index, and pl.when skips
        # the compute, so dead cache rows are neither fetched nor read.
        last = jnp.maximum(lens[b] - 1, 0) // bk
        return (b, jnp.minimum(j, last), h // group, 0)

    kernel = functools.partial(
        _decode_kernel, scale=scale, block_k=bk, num_kv=num_kv
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hq, num_kv),
        in_specs=[
            pl.BlockSpec((1, 1, 1, D), lambda b, h, j, lens: (b, 0, h, 0)),
            pl.BlockSpec((1, bk, 1, D), kv_map),
            pl.BlockSpec((1, bk, 1, D), kv_map),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, 1, D), lambda b, h, j, lens: (b, 0, h, 0)
        ),
        # VMEM scratch carried across the sequential kv grid dimension.
        scratch_shapes=[
            pltpu.VMEM((1, D), jnp.float32),   # output accumulator
            pltpu.VMEM((1, 1), jnp.float32),   # running max
            pltpu.VMEM((1, 1), jnp.float32),   # running denominator
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(lens, q, k, v)
