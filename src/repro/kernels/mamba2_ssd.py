"""Pallas TPU kernel for the Mamba2 SSD (state-space duality) chunk scan.

The SSD insight: a selective-state-space recurrence
    h_t = exp(A·dt_t) h_{t-1} + dt_t·x_t ⊗ B_t,    y_t = C_t h_t
can be evaluated chunk-wise with matmuls (MXU work) plus a tiny inter-chunk
state carry. For a chunk of length L with inclusive log-decay prefix
s_t = A·Σ_{τ<=t} dt_τ:

    y_intra = ((C Bᵀ) ∘ M) (dt·x)        M[t,τ] = exp(s_t - s_τ)·[τ<=t]
    y_inter = exp(s_t) · (C h_in)
    h_out   = exp(s_L) h_in + Bᵀ diag(exp(s_L - s_t)·dt) x

Grid = (batch, heads, chunks) with chunks innermost (sequential on TPU); the
(N, P) state lives in VMEM scratch across chunk steps. exp arguments are all
<= 0 (A < 0), so the chunk math is numerically tame.

Oracle: ref.ssd_ref (sequential lax.scan). Single B/C group (n_groups=1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hout_ref, h_ref, *,
            chunk, nchunks):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)      # (L, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)       # (L,)
    A = a_ref[0].astype(jnp.float32)               # ()
    Bm = b_ref[0].astype(jnp.float32)              # (L, N)
    Cm = c_ref[0].astype(jnp.float32)              # (L, N)

    s = A * jnp.cumsum(dt)                         # (L,) inclusive, <= 0
    dx = dt[:, None] * x                           # (L, P)

    # intra-chunk: ((C B^T) o M) dx
    g = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (L, L)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    tau_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    causal = t_idx >= tau_idx
    logm = s[:, None] - s[None, :]
    m = jnp.where(causal, jnp.exp(jnp.minimum(logm, 0.0)), 0.0)
    y = jax.lax.dot_general(g * m, dx, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (L, P)

    # inter-chunk: exp(s_t) C_t h_in
    h_in = h_ref[...]                              # (N, P)
    y += jnp.exp(s)[:, None] * jax.lax.dot_general(
        Cm, h_in, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    # state update: h_out = exp(s_L) h_in + B^T diag(exp(s_L - s)) dx
    s_l = s[chunk - 1]
    wts = jnp.exp(s_l - s)[:, None] * dx           # (L, P)
    h_new = jnp.exp(s_l) * h_in + jax.lax.dot_general(
        Bm, wts, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    h_ref[...] = h_new
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(ci == nchunks - 1)
    def _final():
        hout_ref[0, 0] = h_new.astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunked(x, dt, A, B, C, *, chunk: int = 64, interpret: bool = False):
    """x (Bt,T,H,P), dt (Bt,T,H), A (H,), B/C (Bt,T,N) -> y, h_final."""
    Bt, T, H, P = x.shape
    N = B.shape[-1]
    L = min(chunk, T)
    assert T % L == 0, (T, L)
    nchunks = T // L
    grid = (Bt, H, nchunks)

    kernel = functools.partial(_kernel, chunk=L, nchunks=nchunks)
    y, h = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, L, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, L, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, L, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, L, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, L, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, N, P), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct((Bt, H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C)
    return y, h
