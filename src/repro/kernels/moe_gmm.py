"""Pallas TPU grouped matmul (GMM) for MoE expert compute.

After sort-based dispatch, tokens sit in expert-contiguous rows; each
expert e multiplies its row slab x[start_e:start_e+n_e] by its own weight
W[e]. The kernel tiles tokens (Bt) and the output feature dim (Bf); the
grid walks (token tile, feature tile, expert). A token tile may straddle a
group boundary, so each expert pass masks the rows belonging to it and
ACCUMULATES into the output tile — out-tile revisits are sequential on TPU
(expert is the innermost grid dim).

group_offsets (E+1,) comes in via scalar prefetch (it determines the mask,
not the data layout). Oracle: ref.gmm_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(offs_ref, x_ref, w_ref, o_ref, *, block_t, n_experts):
    t = pl.program_id(0)
    e = pl.program_id(2)

    @pl.when(e == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    start = offs_ref[e]
    stop = offs_ref[e + 1]
    row0 = t * block_t
    rows = row0 + jax.lax.broadcasted_iota(jnp.int32, (block_t, 1), 0)
    mask = (rows >= start) & (rows < stop)              # (Bt, 1)

    @pl.when((stop > row0) & (start < row0 + block_t))
    def _acc():
        x = jnp.where(mask, x_ref[...], jnp.zeros_like(x_ref))
        o_ref[...] += jax.lax.dot_general(
            x.astype(jnp.float32), w_ref[0].astype(jnp.float32),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_t", "block_f", "interpret")
)
def gmm(x, w, group_sizes, *, block_t: int = 128, block_f: int = 128,
        interpret: bool = False):
    """x (T, D) rows sorted by expert; w (E, D, F); group_sizes (E,) i32.
    Returns (T, F) with out[i] = x[i] @ w[expert_of(i)]."""
    T, D = x.shape
    E, _, F = w.shape
    bt = min(block_t, T)
    bf = min(block_f, F)
    assert T % bt == 0 and F % bf == 0, (T, bt, F, bf)
    offs = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(group_sizes).astype(jnp.int32)]
    )
    grid = (T // bt, F // bf, E)
    kernel = functools.partial(_kernel, block_t=bt, n_experts=E)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                # index maps take the scalar-prefetch ref as a trailing arg
                pl.BlockSpec((bt, D), lambda t, f, e, offs: (t, 0)),
                pl.BlockSpec((1, D, bf), lambda t, f, e, offs: (e, 0, f)),
            ],
            out_specs=pl.BlockSpec((bt, bf), lambda t, f, e, offs: (t, f)),
        ),
        out_shape=jax.ShapeDtypeStruct((T, F), x.dtype),
        interpret=interpret,
    )(offs, x, w)
