"""Dispatch layer: jit'd public entry points that pick the Pallas kernel on
TPU and the pure-jnp oracle elsewhere (this container is CPU-only; kernels
are validated in interpret mode by the test suite, the models call through
here so a TPU deployment gets the kernels with zero code change).
"""
from __future__ import annotations

import jax

from . import ref
from .flash_attention import flash_attention
from .flash_decode import flash_decode
from .paged_decode import paged_decode, paged_prefill
from .mamba2_ssd import ssd_chunked
from .moe_gmm import gmm as gmm_pallas
from .uts_expand import uts_expand


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def attention(q, k, v, *, causal=True, scale=None, impl: str = "auto",
              block_q: int = 128, block_k: int = 128, lengths=None,
              block_tables=None, q_offset=None):
    """impl: auto | pallas | pallas_interpret | ref | chunked
          | decode | decode_interpret | decode_ref
          | paged | paged_interpret | paged_ref

    `lengths` ((B,) i32 visible-window sizes against a padded KV cache)
    plus Sq == 1 selects the split-KV flash-decode fast path: `auto`
    routes such calls to the decode kernel on TPU and the masked-window
    oracle elsewhere; the decode_* impls force one arm.

    `block_tables` ((B, max_blocks) i32) additionally marks k/v as flat
    (num_blocks, block_size, Hkv, D) KV *pools* indirected per sequence
    through the table (serve/kvpool.py): calls route to the paged
    flash-decode kernel on TPU and the gather oracle elsewhere. Every
    impl spelling is normalized so one config knob drives contiguous and
    paged decode alike — the window mask and table walk are never
    dropped.

    `q_offset` ((B,) i32, paged only) marks the call as a *chunked
    prefill*: q holds Sq tokens at absolute positions
    ``[q_offset, q_offset + Sq)`` attending causally to the pool window
    ``[0, lengths)`` — the kernel/oracle pair that lets a long admission
    prefill in budget-sized chunks against blocks earlier chunks (or a
    prefix-cache hit) already wrote.
    """
    if q_offset is not None and block_tables is None:
        raise ValueError(
            "q_offset is a paged chunked-prefill parameter and requires "
            "block_tables; the contiguous paths would silently ignore "
            "the offset and compute wrong attention"
        )
    if lengths is not None and q.shape[1] != 1 and block_tables is None:
        raise ValueError(
            f"lengths is only supported for Sq == 1 decode, got Sq="
            f"{q.shape[1]}; dropping the window mask would silently "
            "attend to dead cache rows"
        )
    if block_tables is not None:
        if lengths is None:
            raise ValueError("block_tables requires lengths")
        impl = {
            "auto": "paged" if _on_tpu() else "paged_ref",
            "pallas": "paged",
            "pallas_interpret": "paged_interpret",
            "ref": "paged_ref",
            "chunked": "paged_ref",
            "decode": "paged",
            "decode_interpret": "paged_interpret",
            "decode_ref": "paged_ref",
        }.get(impl, impl)
        if q_offset is not None or q.shape[1] != 1:
            if q_offset is None:
                raise ValueError(
                    "paged attention with Sq > 1 is chunked prefill and "
                    "requires q_offset (the chunk's start position)"
                )
            if impl == "paged_ref":
                return ref.paged_prefill_ref(q, k, v, block_tables,
                                             lengths, q_offset, scale=scale)
            assert impl in ("paged", "paged_interpret"), impl
            return paged_prefill(q, k, v, block_tables, lengths, q_offset,
                                 scale=scale,
                                 interpret=(impl == "paged_interpret"))
        if impl == "paged_ref":
            return ref.paged_decode_ref(q, k, v, block_tables, lengths,
                                        scale=scale)
        assert impl in ("paged", "paged_interpret"), impl
        return paged_decode(q, k, v, block_tables, lengths, scale=scale,
                            interpret=(impl == "paged_interpret"))
    is_decode = lengths is not None
    if is_decode:
        # Normalize the prefill impl names so one config knob drives both
        # paths: the window mask must never be dropped once lengths are in.
        impl = {
            "auto": "decode" if _on_tpu() else "decode_ref",
            "pallas": "decode",
            "pallas_interpret": "decode_interpret",
            "ref": "decode_ref",
            "chunked": "decode_ref",
        }.get(impl, impl)
    elif impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl in ("decode", "decode_interpret", "decode_ref"):
        assert is_decode, "decode impls need Sq == 1 and lengths"
        if impl == "decode_ref":
            return ref.decode_ref(q, k, v, lengths, scale=scale)
        return flash_decode(q, k, v, lengths, scale=scale,
                            interpret=(impl == "decode_interpret"))
    if impl == "ref":
        return ref.attention_ref(q, k, v, causal=causal, scale=scale)
    if impl == "chunked":
        return ref.attention_chunked(q, k, v, causal=causal, scale=scale,
                                     block_q=block_q if block_q > 128 else 512)
    return flash_attention(
        q, k, v, causal=causal, scale=scale, block_q=block_q, block_k=block_k,
        interpret=(impl == "pallas_interpret"),
    )


def ssd(x, dt, A, B, C, *, chunk: int = 64, impl: str = "auto"):
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return ref.ssd_ref(x, dt, A, B, C)
    if impl == "chunked":
        return ref.ssd_chunked_ref(x, dt, A, B, C, chunk=max(chunk, 128))
    return ssd_chunked(x, dt, A, B, C, chunk=chunk,
                       interpret=(impl == "pallas_interpret"))


def gmm(x, w, group_sizes, *, impl: str = "auto", block_t=128, block_f=128):
    """Grouped matmul for sort-dispatched MoE expert compute."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return ref.gmm_ref(x, w, group_sizes)
    return gmm_pallas(x, w, group_sizes, block_t=block_t, block_f=block_f,
                      interpret=(impl == "pallas_interpret"))


def expand_uts(d0, d1, base, thresholds, *, width=64, impl: str = "auto"):
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return ref.uts_expand_ref(d0, d1, base, thresholds, width)
    return uts_expand(d0, d1, base, thresholds, width=width,
                      interpret=(impl == "pallas_interpret"))
