"""Paged split-KV Pallas flash-decode kernel: one query token against a
block-table-indirected KV pool (Sq == 1, the paged serving hot path) —
plus ``paged_prefill``, the same block walk for a *chunk* of Sq query
tokens at offset ``q_offset`` (chunked prefill: tokens ``[s, e)``
attending causally to pool blocks ``[0, e)``).

flash_decode.py streams a *contiguous* per-slot cache; here the cache is
a flat pool of KV blocks shared by every sequence (serve/kvpool.py) and
each sequence names its blocks through a block table. Two scalar-prefetch
operands — ``block_tables`` (B, max_blocks) i32 and ``lengths`` (B,) i32
— arrive before the kernel body runs, so the k/v **index maps walk the
table**: grid step ``(b, h, j)`` fetches physical block
``block_tables[b, min(j, last_live(b))]`` instead of row-range
``[j*bk, (j+1)*bk)`` of a dense cache. The same three-level gating as
the contiguous kernel applies:

  * DMA clamp   — past-window grid steps clamp the *logical* block index
    to the last live one; the table lookup then repeats the same physical
    block, the pipeline sees an unchanged index and issues no DMA — dead
    blocks are never fetched;
  * block skip  — ``pl.when`` drops compute for blocks at or past the
    window (idle slots, window == 0, skip everything and emit zeros);
  * lane mask   — the partial tail block masks key positions >= window.

The KV block size is the pool's block size (one pool block per grid
step), chosen by ``core.autotune.paged_block_kv``; GQA rides on the
kv-head index map (h // group) as everywhere else. Oracle:
``ref.paged_decode_ref`` (gather blocks -> decode_ref). Routed via
``ops.attention(..., block_tables=...)``; validated in interpret mode on
CPU.

``paged_prefill`` generalizes the decode kernel to an (Sq, D) query
block and a third scalar-prefetch operand ``q_offset`` ((B,) i32 chunk
start): the mask becomes causal-by-absolute-position
(``kpos <= q_offset + i``) intersected with the ``lengths`` window, the
online-softmax scratch grows to (Sq, 1)/(Sq, D), and everything else —
table walk, DMA clamp, block skip, lane mask, GQA — is unchanged.
Oracle: ``ref.paged_prefill_ref``; routed via
``ops.attention(..., block_tables=..., q_offset=...)``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _paged_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref,
                  m_ref, l_ref, *, scale, block_size, max_blocks):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    n = len_ref[b]  # visible window (tokens) for this slot; 0 => idle

    @pl.when(j * block_size < n)  # skip past-window blocks and idle slots
    def _body():
        q = q_ref[0, 0, 0, :].astype(jnp.float32)[None, :]      # (1, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)               # (Bs, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)               # (Bs, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                               # (1, Bs)
        kpos = j * block_size + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_size), 1
        )
        live = kpos < n
        s = jnp.where(live, s, NEG_INF)
        m_prev = m_ref[...]                                     # (1, 1)
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(live, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)                         # (1, 1)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new
        l_ref[...] = alpha * l_prev + p.sum(axis=-1, keepdims=True)

    @pl.when(j == max_blocks - 1)
    def _finish():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)  # idle slot: acc == 0 -> output 0
        o_ref[0, 0, 0, :] = (acc_ref[...] / l)[0].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_decode(q, k_pool, v_pool, block_tables, lengths, *,
                 scale: float | None = None, interpret: bool = False):
    """q: (B, 1, Hq, D); k_pool, v_pool: (num_blocks, Bs, Hkv, D) flat
    block pools; block_tables: (B, max_blocks) i32 physical block per
    logical block (entries past the allocation may be any value — they
    are clamped away); lengths: (B,) i32 visible window (0 => idle slot,
    output zeros). Returns (B, 1, Hq, D)."""
    from jax.experimental.pallas import tpu as pltpu

    B, Sq, Hq, D = q.shape
    NB, Bs, Hkv, _ = k_pool.shape
    assert Sq == 1, f"paged_decode is Sq==1 only, got {Sq}"
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    max_blocks = block_tables.shape[1]
    scale = float(scale) if scale is not None else 1.0 / np.sqrt(D)
    bt = jnp.asarray(block_tables, jnp.int32)
    lens = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32), (B,))

    def kv_map(b, h, j, bt, lens):
        # Walk the block table. Past-window logical blocks clamp to the
        # last live one so the physical index repeats (no DMA, compute
        # skipped by pl.when); unallocated/garbage table entries are
        # clamped into the pool so the address is always valid.
        last = jnp.maximum(lens[b] - 1, 0) // Bs
        phys = bt[b, jnp.minimum(j, last)]
        return (jnp.clip(phys, 0, NB - 1), 0, h // group, 0)

    kernel = functools.partial(
        _paged_kernel, scale=scale, block_size=Bs, max_blocks=max_blocks
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hq, max_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, 1, D),
                         lambda b, h, j, bt, lens: (b, 0, h, 0)),
            pl.BlockSpec((1, Bs, 1, D), kv_map),
            pl.BlockSpec((1, Bs, 1, D), kv_map),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, 1, D), lambda b, h, j, bt, lens: (b, 0, h, 0)
        ),
        # VMEM scratch carried across the sequential block-walk dimension.
        scratch_shapes=[
            pltpu.VMEM((1, D), jnp.float32),   # output accumulator
            pltpu.VMEM((1, 1), jnp.float32),   # running max
            pltpu.VMEM((1, 1), jnp.float32),   # running denominator
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(bt, lens, q, k_pool, v_pool)


def _paged_prefill_kernel(bt_ref, len_ref, off_ref, q_ref, k_ref, v_ref,
                          o_ref, acc_ref, m_ref, l_ref, *, scale,
                          block_size, max_blocks, sq):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    n = len_ref[b]      # visible window: q_offset + true chunk length
    off = off_ref[b]    # absolute position of query row 0

    @pl.when(j * block_size < n)  # skip blocks wholly past the window
    def _body():
        q = q_ref[0, :, 0, :].astype(jnp.float32)               # (Sq, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)               # (Bs, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)               # (Bs, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                               # (Sq, Bs)
        kpos = j * block_size + jax.lax.broadcasted_iota(
            jnp.int32, (sq, block_size), 1
        )
        qpos = off + jax.lax.broadcasted_iota(
            jnp.int32, (sq, block_size), 0
        )
        # Causal by absolute position, clamped to the window; a kv block
        # entirely after some query row leaves that row's lane mask all
        # dead — p is re-zeroed below so its (m, l) stay untouched.
        live = (kpos <= qpos) & (kpos < n)
        s = jnp.where(live, s, NEG_INF)
        m_prev = m_ref[...]                                     # (Sq, 1)
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(live, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)                         # (Sq, 1)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new
        l_ref[...] = alpha * l_prev + p.sum(axis=-1, keepdims=True)

    @pl.when(j == max_blocks - 1)
    def _finish():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)  # fully-masked row -> output 0
        o_ref[0, :, 0, :] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_prefill(q, k_pool, v_pool, block_tables, lengths, q_offset, *,
                  scale: float | None = None, interpret: bool = False):
    """Chunked-prefill attention against the paged pool: q (B, Sq, Hq, D)
    holds the chunk's Sq query tokens whose absolute positions start at
    ``q_offset`` ((B,) i32); k_pool/v_pool/(B, max_blocks) block_tables
    as in paged_decode; lengths (B,) i32 is the visible window
    ``q_offset + true_chunk_len`` (bucket-padded tail queries emit
    garbage the caller discards). Returns (B, Sq, Hq, D)."""
    from jax.experimental.pallas import tpu as pltpu

    B, Sq, Hq, D = q.shape
    NB, Bs, Hkv, _ = k_pool.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    max_blocks = block_tables.shape[1]
    scale = float(scale) if scale is not None else 1.0 / np.sqrt(D)
    bt = jnp.asarray(block_tables, jnp.int32)
    lens = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32), (B,))
    offs = jnp.broadcast_to(jnp.asarray(q_offset, jnp.int32), (B,))

    def kv_map(b, h, j, bt, lens, offs):
        # Same walk/clamp as decode: past-window logical blocks repeat
        # the last live physical block (no DMA, compute skipped).
        last = jnp.maximum(lens[b] - 1, 0) // Bs
        phys = bt[b, jnp.minimum(j, last)]
        return (jnp.clip(phys, 0, NB - 1), 0, h // group, 0)

    kernel = functools.partial(
        _paged_prefill_kernel, scale=scale, block_size=Bs,
        max_blocks=max_blocks, sq=Sq,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, Hq, max_blocks),
        in_specs=[
            pl.BlockSpec((1, Sq, 1, D),
                         lambda b, h, j, bt, lens, offs: (b, 0, h, 0)),
            pl.BlockSpec((1, Bs, 1, D), kv_map),
            pl.BlockSpec((1, Bs, 1, D), kv_map),
        ],
        out_specs=pl.BlockSpec(
            (1, Sq, 1, D), lambda b, h, j, bt, lens, offs: (b, 0, h, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((Sq, D), jnp.float32),   # output accumulator
            pltpu.VMEM((Sq, 1), jnp.float32),   # running max
            pltpu.VMEM((Sq, 1), jnp.float32),   # running denominator
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(bt, lens, offs, q, k_pool, v_pool)
