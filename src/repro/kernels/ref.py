"""Pure-jnp oracles for every Pallas kernel (the correctness references).

Each kernel in this package has exactly one oracle here; kernel tests sweep
shapes/dtypes and assert_allclose against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.problems.uts import child_hash, child_count


# ----------------------------------------------------------- uts_expand
def uts_expand_ref(d0, d1, base, thresholds, width: int, max_depth_ok=None):
    """Expand a block of M UTS nodes: child descriptors + geometric child
    counts for `width` consecutive child indices starting at `base`.

    d0, d1: (M,) uint32 parent descriptors; base: (M,) i32.
    Returns cd0, cd1 (M, width) uint32 and m (M, width) i32 (count BEFORE the
    depth cut-off is applied — the caller owns depth logic)."""
    idx = base[:, None] + jnp.arange(width, dtype=jnp.int32)[None, :]
    cd0, cd1 = child_hash(d0[:, None], d1[:, None], idx, jnp)
    m = child_count(cd0, thresholds, jnp)
    return cd0, cd1, m


# ------------------------------------------------------ flash_attention
def attention_ref(q, k, v, causal: bool = True, scale: float | None = None):
    """Plain softmax attention with GQA; q (B,Sq,Hq,D), k/v (B,Skv,Hkv,D)."""
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    assert Hq % Hkv == 0
    group = Hq // Hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    kx = jnp.repeat(k, group, axis=2)
    vx = jnp.repeat(v, group, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        kx.astype(jnp.float32)) * scale
    if causal:
        # decode layout: query i sits at absolute position Skv - Sq + i
        qpos = jnp.arange(Sq)[:, None] + (Skv - Sq)
        kpos = jnp.arange(Skv)[None, :]
        logits = jnp.where(qpos >= kpos, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vx.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_ref(q, k, v, lengths, scale: float | None = None):
    """Sq==1 attention against a padded KV cache (the flash_decode oracle):
    mask = kpos < length, so row b matches attention_ref on k[b, :length].
    Rows with length == 0 are idle serving slots — the fully-masked softmax
    degenerates to uniform probs and callers ignore the output.

    q (B,1,Hq,hd); k, v (B,S,Hkv,hd); lengths (B,) i32. f32 softmax."""
    B, S, Hkv, hd = k.shape
    Hq = q.shape[2]
    group = Hq // Hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)
    lens = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32), (B,))
    qf = q.astype(jnp.float32).reshape(B, Hkv, group, hd)
    kf = k.astype(jnp.float32)
    s = jnp.einsum("bgqd,bsgd->bgqs", qf, kf) * scale        # (B,Hkv,grp,S)
    mask = jnp.arange(S)[None, :] < lens[:, None]            # (B, S)
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgqs,bsgd->bgqd", p, v.astype(jnp.float32))
    return o.reshape(B, 1, Hq, hd).astype(q.dtype)


def paged_decode_ref(q, k_pool, v_pool, block_tables, lengths,
                     scale: float | None = None):
    """Sq==1 attention against a block-table-indirected KV pool (the
    paged_decode oracle): gather each sequence's blocks into its logical
    order, then decode_ref with the same window mask. Garbage table
    entries past the allocation are clamped into the pool — the window
    mask keeps their rows invisible.

    q (B,1,Hq,hd); k_pool/v_pool (num_blocks, Bs, Hkv, hd);
    block_tables (B, max_blocks) i32; lengths (B,) i32."""
    B = q.shape[0]
    NB, Bs, Hkv, hd = k_pool.shape
    bt = jnp.clip(jnp.asarray(block_tables, jnp.int32), 0, NB - 1)
    k = k_pool[bt].reshape(B, -1, Hkv, hd)      # (B, max_blocks*Bs, ...)
    v = v_pool[bt].reshape(B, -1, Hkv, hd)
    return decode_ref(q, k, v, lengths, scale=scale)


def paged_prefill_ref(q, k_pool, v_pool, block_tables, lengths, q_offset,
                      scale: float | None = None):
    """Chunked-prefill attention against the paged pool (the
    paged_prefill oracle): queries are tokens ``[s, s + Sq)`` of a
    sequence whose KV for ``[0, s + Sq)`` already sits in pool blocks
    (earlier chunks / a prefix-cache hit, plus this chunk's own rows,
    written by the caller before attending). Causal: query ``i`` sees
    key positions ``<= q_offset + i``, additionally clamped to the
    ``lengths`` window so bucket-padded tail queries read no stale rows.

    q (B,Sq,Hq,hd); k_pool/v_pool (num_blocks, Bs, Hkv, hd);
    block_tables (B, max_blocks) i32; lengths (B,) i32 visible window
    (= q_offset + true chunk length); q_offset (B,) i32 chunk start."""
    B, Sq, Hq, hd = q.shape
    NB, Bs, Hkv, _ = k_pool.shape
    group = Hq // Hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)
    bt = jnp.clip(jnp.asarray(block_tables, jnp.int32), 0, NB - 1)
    k = k_pool[bt].reshape(B, -1, Hkv, hd)      # logical order gather
    v = v_pool[bt].reshape(B, -1, Hkv, hd)
    S = k.shape[1]
    lens = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32), (B,))
    offs = jnp.broadcast_to(jnp.asarray(q_offset, jnp.int32), (B,))
    kx = jnp.repeat(k, group, axis=2).astype(jnp.float32)
    vx = jnp.repeat(v, group, axis=2).astype(jnp.float32)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        kx) * scale
    qpos = offs[:, None] + jnp.arange(Sq, dtype=jnp.int32)[None, :]  # (B,Sq)
    kpos = jnp.arange(S, dtype=jnp.int32)
    mask = (kpos[None, None, :] <= qpos[:, :, None]) & (
        kpos[None, None, :] < lens[:, None, None]
    )                                                                # (B,Sq,S)
    logits = jnp.where(mask[:, None, :, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vx)
    return out.astype(q.dtype)


def attention_chunked(q, k, v, causal: bool = True, scale: float | None = None,
                      block_q: int = 512):
    """Memory-bounded attention: lax.map over q blocks, full kv per block
    (scores (B,H,Bq,Skv) transient instead of (B,H,Sq,Skv)). Each block is
    jax.checkpoint-ed so the BACKWARD also recomputes per-block probs (the
    flash-backward pattern) instead of saving (B,H,Sq,Skv). GQA contracts
    against the raw (B,S,Hkv,D) kv — no repeated-kv materialization.

    NOTE for roofline: XLA cost_analysis counts the q-block loop body once —
    analysis code adds the analytic correction (launch/dryrun.py)."""
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    group = Hq // Hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    bq = min(block_q, Sq)
    assert Sq % bq == 0, (Sq, bq)
    nblk = Sq // bq
    kpos = jnp.arange(Skv)[None, :]

    @jax.checkpoint
    def one_block(qb, i):
        qg = qb.reshape(B, bq, Hkv, group, D).astype(jnp.float32)
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg,
                            k.astype(jnp.float32)) * scale
        if causal:
            qpos = (i * bq + jnp.arange(bq))[:, None] + (Skv - Sq)
            logits = jnp.where(qpos >= kpos, logits, -1e30)
        p = jax.nn.softmax(logits, axis=-1)
        o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
        return o.reshape(B, bq, Hq, D).astype(q.dtype)

    def body(i):
        qb = jax.lax.dynamic_slice_in_dim(q, i * bq, bq, axis=1)
        return one_block(qb, i)

    blocks = jax.lax.map(body, jnp.arange(nblk))        # (nblk,B,bq,H,D)
    return jnp.moveaxis(blocks, 0, 1).reshape(B, Sq, Hq, D)


# ----------------------------------------------------------- mamba2_ssd
def ssd_ref(x, dt, A, B, C, h0=None):
    """Sequential state-space scan — the Mamba2 SSD semantics.

    x:  (Bt, T, H, P)   inputs per head
    dt: (Bt, T, H)      positive step sizes
    A:  (H,)            negative decay rates
    B:  (Bt, T, N)      input projections (single group)
    C:  (Bt, T, N)      output projections
    h0: optional (Bt, H, N, P) initial state
    Returns y (Bt, T, H, P), h_final (Bt, H, N, P). All math f32."""
    Bt, T, H, P = x.shape
    N = B.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)
    Af = A.astype(jnp.float32)

    def step(h, t):
        a = jnp.exp(Af[None, :] * dtf[:, t])                # (Bt, H)
        dx = dtf[:, t, :, None] * xf[:, t]                  # (Bt, H, P)
        upd = Bf[:, t, None, :, None] * dx[:, :, None, :]   # (Bt, H, N, P)
        h = h * a[:, :, None, None] + upd
        y = jnp.einsum("bn,bhnp->bhp", Cf[:, t], h)         # (Bt, H, P)
        return h, y

    h = (jnp.zeros((Bt, H, N, P), jnp.float32) if h0 is None
         else h0.astype(jnp.float32))
    h, ys = jax.lax.scan(step, h, jnp.arange(T))
    y = jnp.moveaxis(ys, 0, 1)  # (Bt, T, H, P)
    return y.astype(x.dtype), h


def ssd_chunked_ref(x, dt, A, B, C, chunk: int = 256):
    """Chunk-matmul SSD (same math as the Pallas kernel, pure jnp): scan
    over T/chunk chunks, matmuls inside. This is the form the dry-run
    compiles for long sequences (the sequential scan would be a T-trip
    while loop). Matches ssd_ref to fp tolerance."""
    Bt, T, H, P = x.shape
    N = B.shape[-1]
    L = min(chunk, T)
    assert T % L == 0
    nck = T // L
    xf = x.astype(jnp.float32).reshape(Bt, nck, L, H, P)
    dtf = dt.astype(jnp.float32).reshape(Bt, nck, L, H)
    Bf = B.astype(jnp.float32).reshape(Bt, nck, L, N)
    Cf = C.astype(jnp.float32).reshape(Bt, nck, L, N)
    Af = A.astype(jnp.float32)
    tri = jnp.tril(jnp.ones((L, L), jnp.float32))

    def step(h, ck):
        xc, dtc, Bc, Cc = ck                       # (Bt,L,H,P),(Bt,L,H),...
        s = Af[None, None, :] * jnp.cumsum(dtc, axis=1)      # (Bt,L,H)
        dx = dtc[..., None] * xc                             # (Bt,L,H,P)
        G = jnp.einsum("btn,bun->btu", Cc, Bc)               # (Bt,L,L)
        logm = s[:, :, None] - s[:, None, :]                 # (Bt,L,L,H)
        M = jnp.exp(jnp.minimum(logm, 0.0)) * tri[None, :, :, None]
        y = jnp.einsum("btu,btuh,buhp->bthp", G, M, dx)
        y = y + jnp.exp(s)[..., None] * jnp.einsum(
            "btn,bhnp->bthp", Cc, h
        )
        s_l = s[:, -1]                                       # (Bt,H)
        wts = jnp.exp(s_l[:, None] - s)[..., None] * dx      # (Bt,L,H,P)
        h = jnp.exp(s_l)[:, :, None, None] * h + jnp.einsum(
            "bun,buhp->bhnp", Bc, wts
        )
        return h, y

    h0 = jnp.zeros((Bt, H, N, P), jnp.float32)
    h, ys = jax.lax.scan(
        step, h0,
        (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
         jnp.moveaxis(Bf, 1, 0), jnp.moveaxis(Cf, 1, 0)),
    )
    y = jnp.moveaxis(ys, 0, 1).reshape(Bt, T, H, P)
    return y.astype(x.dtype), h


# -------------------------------------------------------------- moe_gmm
def gmm_ref(x, w, group_sizes):
    """Grouped matmul: rows of x are sorted by expert; group_sizes (E,) give
    each expert's row count. out[i] = x[i] @ w[expert_of(i)]."""
    T, D = x.shape
    E, _, F = w.shape
    bounds = jnp.cumsum(group_sizes)
    expert_of = jnp.searchsorted(bounds, jnp.arange(T), side="right")
    expert_of = jnp.clip(expert_of, 0, E - 1)
    return jnp.einsum(
        "td,tdf->tf", x.astype(jnp.float32),
        w.astype(jnp.float32)[expert_of],
    ).astype(x.dtype)
