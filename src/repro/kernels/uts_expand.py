"""Pallas TPU kernel for the UTS hot loop: batched node expansion.

The paper's ``process(n)`` spends all its time hashing child descriptors and
sampling geometric child counts (§2.5.2). That is pure VPU work: 32-bit
integer mixing over a (nodes × width) block. The kernel expands a block of M
nodes × W child indices per grid step, entirely in VMEM.

Geometric sampling is a table of 32 integer threshold compares (bit-exact
with the python oracle; see problems/uts.py).

Oracle: ref.uts_expand_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.problems.uts import MAX_CHILD, _C1, _C2, _C3, _C4


def _fmix32(h):
    h = h ^ (h >> jnp.uint32(16))
    h = h * jnp.uint32(_C1)
    h = h ^ (h >> jnp.uint32(15))
    h = h * jnp.uint32(_C2)
    h = h ^ (h >> jnp.uint32(16))
    return h


def _kernel(d0_ref, d1_ref, base_ref, thr_ref, cd0_ref, cd1_ref, m_ref, *,
            width):
    mb = d0_ref.shape[0]
    lane = jax.lax.broadcasted_iota(jnp.int32, (mb, width), 1)
    idx = (base_ref[...][:, None] + lane).astype(jnp.uint32)
    d0 = d0_ref[...][:, None]
    d1 = d1_ref[...][:, None]
    h0 = _fmix32(d0 + idx * jnp.uint32(_C3))
    h1 = _fmix32((d1 ^ h0) + idx * jnp.uint32(_C4))
    h0 = _fmix32(h0 ^ h1)
    cd0_ref[...] = h0
    cd1_ref[...] = h1
    # geometric child count: #{k : u < T_k} over the threshold table
    thr = thr_ref[...]  # (MAX_CHILD,)
    m = jnp.zeros((mb, width), jnp.int32)
    for kk in range(MAX_CHILD):  # static unroll; VPU compares
        m = m + (h0 < thr[kk]).astype(jnp.int32)
    m_ref[...] = m


@functools.partial(jax.jit, static_argnames=("width", "block_m", "interpret"))
def uts_expand(d0, d1, base, thresholds, *, width: int = 64,
               block_m: int = 128, interpret: bool = False):
    """d0,d1 (M,) uint32; base (M,) i32; thresholds (MAX_CHILD,) uint32.
    Returns cd0, cd1 (M, width) uint32 and m (M, width) i32."""
    M = d0.shape[0]
    mb = min(block_m, M)
    assert M % mb == 0, (M, mb)
    grid = (M // mb,)
    kernel = functools.partial(_kernel, width=width)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((mb,), lambda i: (i,)),
            pl.BlockSpec((mb,), lambda i: (i,)),
            pl.BlockSpec((mb,), lambda i: (i,)),
            pl.BlockSpec((MAX_CHILD,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((mb, width), lambda i: (i, 0)),
            pl.BlockSpec((mb, width), lambda i: (i, 0)),
            pl.BlockSpec((mb, width), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, width), jnp.uint32),
            jax.ShapeDtypeStruct((M, width), jnp.uint32),
            jax.ShapeDtypeStruct((M, width), jnp.int32),
        ],
        interpret=interpret,
    )(d0, d1, base, thresholds)
