"""Launchers: production meshes, multi-pod dry-run, train/serve drivers.
NOTE: do NOT import dryrun from here — it sets XLA_FLAGS at import time."""
