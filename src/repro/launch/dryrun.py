import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first
# init, and the production meshes below need 512 placeholder devices.

"""Multi-pod dry-run: prove the distribution config is coherent without
hardware.

For every (architecture x input-shape) cell — plus the paper's own GLB
workloads (UTS-G, BC-G) — this lowers + compiles the step function on the
single-pod 16x16 mesh and the 2x16x16 multi-pod mesh, records
memory_analysis / cost_analysis / collective bytes, and derives the
roofline terms (EXPERIMENTS.md §Dry-run, §Roofline).

Usage:
  python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
  python -m repro.launch.dryrun --arch uts_glb --shape glb
  python -m repro.launch.dryrun --all [--multi-pod] [--out artifacts/dryrun]
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import numpy as np

from repro.analysis.hlo import collective_bytes
from repro.analysis import roofline as rl
from repro.configs import ARCHS, SHAPES, cell_applicable, get_config, input_specs
from repro.dist.sharding import (
    batch_axes, cache_axes, opt_axes, param_axes, tree_shardings,
)
from repro.launch.mesh import make_glb_mesh, make_production_mesh
from repro.models import init_lm, make_cache
from repro.models.config import ShapeConfig
from repro.train.optimizer import OptConfig, adamw_init
from repro.train.trainer import make_decode_step, make_prefill_step, make_train_step

GLB_CELLS = ("uts_glb", "bc_glb")


def _sds(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree
    )


MOE_IMPL = os.environ.get("REPRO_MOE_IMPL", "auto")  # auto|global|ep
MICROBATCH = int(os.environ.get("REPRO_MICROBATCH", "1"))  # train cells
REMAT = os.environ.get("REPRO_REMAT", "")  # ''=arch default | none|dots|full


def _cell_cfg(cfg, shape):
    """Per-cell impl overrides: long sequences compile the chunked (flash-
    style) attention / chunk-matmul SSD so the deployable program's memory
    is bounded; decode uses the masked full-cache path (no inner loops).
    REPRO_MOE_IMPL=global reproduces the §Perf baseline dispatch."""
    impl = "chunked" if shape.kind in ("train", "prefill") else "ref"
    kw = dict(attn_impl=impl, moe_impl=MOE_IMPL)
    if REMAT:
        kw["remat"] = REMAT
    return dataclasses.replace(cfg, **kw)


def lower_lm_cell(arch: str, shape_name: str, multi_pod: bool,
                  n_layers: int = 0, scan: bool = True):
    cfg = _cell_cfg(get_config(arch), SHAPES[shape_name])
    if n_layers:
        cfg = dataclasses.replace(cfg, n_layers=n_layers, scan_layers=False)
    if not scan:
        cfg = dataclasses.replace(cfg, scan_layers=False)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)

    pshapes = jax.eval_shape(lambda: init_lm(jax.random.key(0), cfg))
    paxes = param_axes(cfg, pshapes=pshapes)
    pshard = tree_shardings(paxes, pshapes, mesh)
    baxes = batch_axes(cfg, shape.kind)
    batch = input_specs(cfg, shape)
    if shape.kind == "train":
        oshapes = jax.eval_shape(lambda: adamw_init(pshapes))
        oshard = tree_shardings(opt_axes(paxes), oshapes, mesh)
        bshard = tree_shardings(baxes, batch, mesh)
        step = make_train_step(cfg, OptConfig(), microbatches=MICROBATCH)
        jitted = jax.jit(
            step,
            in_shardings=(pshard, oshard, bshard),
            out_shardings=(pshard, oshard, None),
            donate_argnums=(0, 1),
        )
        with jax.sharding.set_mesh(mesh):
            lowered = jitted.lower(pshapes, oshapes, batch)
    elif shape.kind == "prefill":
        bshard = tree_shardings(baxes, batch, mesh)
        step = make_prefill_step(cfg, max_seq=shape.seq_len)
        cshapes = jax.eval_shape(
            lambda: make_cache(cfg, shape.global_batch, shape.seq_len)
        )
        cshard = tree_shardings(cache_axes(cfg), cshapes, mesh)
        jitted = jax.jit(
            step, in_shardings=(pshard, bshard),
            out_shardings=(None, cshard),
        )
        with jax.sharding.set_mesh(mesh):
            lowered = jitted.lower(pshapes, batch)
    else:  # decode
        bshard = tree_shardings(baxes, batch, mesh)
        step = make_decode_step(cfg)
        jitted = jax.jit(
            step,
            in_shardings=(pshard, bshard["tokens"], bshard["cache"],
                          bshard["cache_len"]),
            out_shardings=(None, bshard["cache"]),
            donate_argnums=(2,),
        )
        with jax.sharding.set_mesh(mesh):
            lowered = jitted.lower(
                pshapes, batch["tokens"], batch["cache"], batch["cache_len"]
            )
    return lowered, mesh, cfg, shape


def lower_glb_cell(which: str, multi_pod: bool):
    from repro.core import GLBParams, lower_shardmap
    from repro.problems.bc import bc_problem
    from repro.problems.rmat import rmat_graph
    from repro.problems.uts import uts_problem

    mesh = make_glb_mesh(multi_pod=multi_pod)
    routing = os.environ.get("REPRO_GLB_ROUTING", "dense")
    params = GLBParams(
        n=256,
        w=int(os.environ.get("REPRO_GLB_W", "2")),
        steal_k=64,
        steal_k_random=int(os.environ.get("REPRO_GLB_KRAND", "0")),
        max_supersteps=100_000,
    )
    if which == "uts_glb":
        prob = uts_problem(b0=4.0, depth=16, seed=19, capacity=8192)
    else:
        adj, _ = rmat_graph(scale=10, seed=7)   # N=1024, replicated graph
        prob = bc_problem(adj, capacity=2048)
    lowered = lower_shardmap(prob, mesh, params, axis="place",
                             routing=routing)
    shape = ShapeConfig(which, 0, mesh.shape["place"], "glb")
    return lowered, mesh, None, shape


# ------------------------------------------------------- cost extraction
def _cost_of(lowered):
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": coll,
    }


def _lin(c1, c2, n1, n2, n):
    """Linear extrapolation of per-device cost dicts in layer count."""
    per = {
        "flops": (c2["flops"] - c1["flops"]) / (n2 - n1),
        "bytes": (c2["bytes"] - c1["bytes"]) / (n2 - n1),
        "coll": (c2["coll"].get("total", 0) - c1["coll"].get("total", 0))
        / (n2 - n1),
    }
    return {
        "flops": c1["flops"] + per["flops"] * (n - n1),
        "bytes": c1["bytes"] + per["bytes"] * (n - n1),
        "coll_total": c1["coll"].get("total", 0) + per["coll"] * (n - n1),
    }


def loop_corrections(cfg, shape, chips: int):
    """Analytic per-device (flops, bytes) for compute inside intra-layer
    loops (chunked attention q-block map; chunked SSD scan), which XLA's
    cost_analysis counts only once. Returns the MISSING portion
    (true * (1 - 1/trips)), global/chips. See EXPERIMENTS.md §Method."""
    if shape.kind == "decode":
        return 0.0, 0.0, "none (no intra-layer loops in decode)"
    B, S = shape.global_batch, shape.seq_len
    factor = 4.0 if shape.kind == "train" else 1.0  # fwd+2bwd+remat-refwd
    flops = bytes_ = 0.0
    notes = []
    if cfg.n_heads:
        bq = int(os.environ.get("REPRO_ATTN_BLOCK", "512"))
        nblk = max(S // bq, 1)
        attn = 4.0 * B * S * S * cfg.n_heads * cfg.hd * 0.5  # causal
        kvbytes = nblk * S * cfg.n_kv_heads * cfg.hd * 2 * 2  # re-read k,v
        napps = (cfg.n_layers // cfg.attn_every
                 if cfg.family == "hybrid" else cfg.n_layers)
        miss = (1 - 1.0 / nblk)
        flops += attn * napps * factor * miss
        bytes_ += kvbytes * B * napps * factor * miss
        notes.append(f"attn x{napps} layers, {nblk} q-blocks")
    if cfg.family in ("ssm", "hybrid"):
        L = 256
        nck = max(S // L, 1)
        H, N, Pd = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_headdim
        per_chunk = 2.0 * L * L * (N + H * Pd) + 4.0 * L * H * N * Pd
        ssd = per_chunk * nck * B * cfg.n_layers
        miss = (1 - 1.0 / nck)
        flops += ssd * factor * miss
        notes.append(f"ssd x{cfg.n_layers} layers, {nck} chunks")
    return flops / chips, bytes_ / chips, "; ".join(notes) or "none"


def analyze_cost(arch: str, shape_name: str, chips: int):
    """Per-layer cost deltas from reduced-depth UNROLLED compiles,
    extrapolated to the full depth (exact for homogeneous stacks), plus
    analytic corrections for intra-layer loops."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if cfg.family == "hybrid":
        p = cfg.attn_every
        l6, _, _, _ = lower_lm_cell(arch, shape_name, False, n_layers=p)
        l7, _, _, _ = lower_lm_cell(arch, shape_name, False, n_layers=p + 1)
        l12, _, _, _ = lower_lm_cell(arch, shape_name, False, n_layers=2 * p)
        c6, c7, c12 = _cost_of(l6), _cost_of(l7), _cost_of(l12)
        napps = cfg.n_layers // p
        extra = cfg.n_layers - p - (napps - 1) * p
        agg = {}
        for key in ("flops", "bytes"):
            agg[key] = (c6[key] + (napps - 1) * (c12[key] - c6[key])
                        + extra * (c7[key] - c6[key]))
        coll = (c6["coll"].get("total", 0)
                + (napps - 1) * (c12["coll"].get("total", 0)
                                 - c6["coll"].get("total", 0))
                + extra * (c7["coll"].get("total", 0)
                           - c6["coll"].get("total", 0)))
        out = {"flops": agg["flops"], "bytes": agg["bytes"],
               "coll_total": coll}
    else:
        l1, _, _, _ = lower_lm_cell(arch, shape_name, False, n_layers=1)
        l2, _, _, _ = lower_lm_cell(arch, shape_name, False, n_layers=2)
        c1, c2 = _cost_of(l1), _cost_of(l2)
        out = _lin(c1, c2, 1, 2, cfg.n_layers)
    df, db, note = loop_corrections(cfg, shape, chips)
    out["flops_corrected"] = out["flops"] + df
    out["bytes_corrected"] = out["bytes"] + db
    out["correction_note"] = note
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str):
    t0 = time.time()
    label = f"{arch}/{shape_name}/{'multipod' if multi_pod else 'pod'}"
    rec = {"arch": arch, "shape": shape_name, "multi_pod": multi_pod}
    try:
        if arch in GLB_CELLS:
            lowered, mesh, cfg, shape = lower_glb_cell(arch, multi_pod)
        else:
            cfg0 = get_config(arch)
            ok, why = cell_applicable(cfg0, SHAPES[shape_name])
            if not ok:
                rec.update(status="skipped", reason=why)
                return _save(rec, out_dir, label)
            lowered, mesh, cfg, shape = lower_lm_cell(arch, shape_name, multi_pod)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        chips = int(np.prod(list(mesh.shape.values())))

        mem = {}
        try:
            ma = compiled.memory_analysis()
            for f in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes"):
                if hasattr(ma, f):
                    mem[f] = int(getattr(ma, f))
        except Exception as e:  # noqa: BLE001
            mem["error"] = repr(e)

        hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        if arch in GLB_CELLS:
            mflops = 0.0
            roof = rl.build(compiled, coll, chips, 0.0)
        else:
            mflops = rl.model_flops(cfg, shape)
            if not multi_pod:
                # layer-extrapolated, loop-corrected cost (the scanned
                # compile undercounts while-loop bodies); raw kept alongside
                cx = analyze_cost(arch, shape_name, chips)
                rec["cost_extrapolated"] = {
                    k: (round(v, 1) if isinstance(v, float) else v)
                    for k, v in cx.items()
                }
                roof = rl.Roofline(
                    flops=cx["flops_corrected"],
                    bytes_accessed=cx["bytes_corrected"],
                    collective={"total": cx["coll_total"]},
                    chips=chips,
                    model_flops=mflops,
                ).finalize()
            else:
                roof = rl.build(compiled, coll, chips, mflops)
        rec.update(
            status="ok",
            chips=chips,
            mesh={k: int(v) for k, v in mesh.shape.items()},
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            memory_analysis=mem,
            collective_bytes=coll,
            cost={
                "flops_per_dev": roof.flops,
                "bytes_per_dev": roof.bytes_accessed,
            },
            model_flops=mflops,
            roofline=roof.row(),
            hlo_bytes=len(hlo),
        )
    except Exception as e:  # noqa: BLE001
        rec.update(status="error", error=repr(e),
                   trace=traceback.format_exc()[-4000:])
    return _save(rec, out_dir, label)


def _save(rec, out_dir, label):
    os.makedirs(out_dir, exist_ok=True)
    fname = label.replace("/", "__") + ".json"
    path = os.path.join(out_dir, fname)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    status = rec.get("status")
    extra = ""
    if status == "ok":
        extra = (f" chips={rec['chips']} compile={rec['compile_s']}s "
                 f"bottleneck={rec['roofline']['bottleneck']}")
    elif status == "error":
        extra = " " + rec.get("error", "")[:120]
    print(f"[dryrun] {label}: {status}{extra}", flush=True)
    return rec


def all_cells():
    cells = []
    for arch in sorted(ARCHS):
        for shape in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
            cells.append((arch, shape))
    cells += [(g, "glb") for g in GLB_CELLS]
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]
    if args.all:
        for arch, shape in all_cells():
            for mp in meshes:
                run_cell(arch, shape, mp, args.out)
    else:
        assert args.arch, "--arch required without --all"
        for mp in meshes:
            run_cell(args.arch, args.shape, mp, args.out)


if __name__ == "__main__":
    main()
