"""Production mesh builders. Functions (not module constants) so importing
never touches jax device state."""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model). Multi-pod: 2 pods x 256
    chips with a leading `pod` axis (DP across pods over DCN)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_glb_mesh(*, multi_pod: bool = False):
    """1-D place mesh for the paper's own GLB workloads (one place per
    chip): 256 places single-pod, 512 multi-pod."""
    n = 512 if multi_pod else 256
    return jax.make_mesh((n,), ("place",), axis_types=(AxisType.Auto,))


def make_host_mesh(n: int = 1, axis: str = "place"):
    """Small mesh over however many (host) devices exist — tests/examples."""
    n = min(n, len(jax.devices()))
    return jax.make_mesh((n,), (axis,), axis_types=(AxisType.Auto,))
