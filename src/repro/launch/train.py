"""End-to-end training driver.

Features exercised by tests/examples:
  - presets (tiny / 100m / full) scaled from any --arch config
  - deterministic, checkpointable data pipeline
  - periodic (optionally async) checkpoints; --resume restores params, opt
    state, data-iterator state and PRNG and replays bit-identically
  - --fail-at-step N simulates a node failure (the FT drill: launcher
    restarts with --resume and must reach the same final state)
  - GLB-MoE expert rebalancing every --rebalance-every steps (moe archs)
  - elastic: restore works under a different device mesh (shardings are
    applied at device_put time)

Example:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b \
      --preset tiny --steps 60 --ckpt-dir /tmp/ckpt --ckpt-every 20
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.pipeline import DataState, SyntheticTokens
from repro.ft import checkpoint as ckpt
from repro.models.config import ModelConfig
from repro.train.optimizer import OptConfig
from repro.train.trainer import init_train_state, make_train_step


def preset_config(cfg: ModelConfig, preset: str) -> ModelConfig:
    if preset == "full":
        return cfg
    if preset == "tiny":
        return dataclasses.replace(
            cfg.smoke(), name=cfg.name + "-tiny", dtype="float32",
        )
    if preset == "100m":
        return dataclasses.replace(
            cfg,
            name=cfg.name + "-100m",
            n_layers=12,
            d_model=768,
            n_heads=12 if cfg.n_heads else 0,
            n_kv_heads=4 if cfg.n_kv_heads else 0,
            head_dim=64 if cfg.n_heads else 0,
            d_ff=2048 if cfg.d_ff else 0,
            vocab=32000,
            n_experts=min(cfg.n_experts, 8),
            top_k=min(cfg.top_k, 2),
            remat="none",
            dtype="float32",
        )
    raise ValueError(preset)


def train(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--preset", default="tiny",
                    choices=["tiny", "100m", "full"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--ckpt-async", action="store_true")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at-step", type=int, default=-1)
    ap.add_argument("--rebalance-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--metrics-out", default="")
    args = ap.parse_args(argv)

    cfg = preset_config(get_config(args.arch), args.preset)
    oc = OptConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5 + 1),
                   total_steps=args.steps)
    data = SyntheticTokens(cfg, args.batch, args.seq, seed=args.seed)

    start_step = 0
    if args.resume and args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        tree, extra, step = ckpt.restore(args.ckpt_dir)
        params, opt = tree["params"], tree["opt"]
        params = jax.tree.map(jnp.asarray, params)
        opt = jax.tree.map(jnp.asarray, opt)
        data.state = DataState.from_dict(extra["data"])
        start_step = step
        print(f"[train] resumed from step {step}")
    else:
        params, opt = init_train_state(jax.random.key(args.seed), cfg)

    step_fn = jax.jit(make_train_step(cfg, oc), donate_argnums=(0, 1))
    history = []
    expert_perm = (np.arange(cfg.n_experts) if cfg.family == "moe" else None)

    t0 = time.time()
    for step in range(start_step, args.steps):
        if step == args.fail_at_step:
            raise RuntimeError(
                f"[train] simulated node failure at step {step}"
            )
        batch = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
        params, opt, metrics = step_fn(params, opt, batch)
        if (step + 1) % args.log_every == 0 or step + 1 == args.steps:
            loss = float(metrics["loss"])
            history.append({"step": step + 1, "loss": loss})
            print(f"[train] step {step+1:5d} loss {loss:.4f} "
                  f"({(time.time()-t0):.1f}s)", flush=True)
        if (cfg.family == "moe" and args.rebalance_every
                and (step + 1) % args.rebalance_every == 0):
            from repro.models.glb_moe import glb_expert_rebalance

            counts = np.asarray(metrics["expert_counts"])
            res = glb_expert_rebalance(counts, expert_perm, n_ranks=4)
            expert_perm = res.perm
            print(f"[train] GLB-MoE rebalance: load std "
                  f"{res.loads_before.std():.1f} -> {res.loads_after.std():.1f}"
                  f" ({len(res.swaps)} swaps)")
        if (args.ckpt_dir and args.ckpt_every
                and (step + 1) % args.ckpt_every == 0):
            ckpt.save(
                args.ckpt_dir, step + 1, {"params": params, "opt": opt},
                extra={"data": data.state.to_dict(),
                       "arch": cfg.name, "seed": args.seed},
                async_=args.ckpt_async,
            )
    if args.metrics_out:
        fingerprint = float(
            sum(float(jnp.sum(jnp.abs(x.astype(jnp.float32))))
                for x in jax.tree.leaves(params))
        )
        with open(args.metrics_out, "w") as f:
            json.dump({"history": history, "fingerprint": fingerprint}, f)
    return params, opt, history


if __name__ == "__main__":
    train()
