"""Model substrate: composable decoder-only LM families (dense GQA, MoE,
Mamba2/SSD, Zamba2-hybrid, VLM/audio backbone stubs)."""
from .config import ModelConfig, ShapeConfig, SHAPES
from .transformer import init_lm, forward, make_cache, make_paged_cache
from .lm import train_loss, prefill, decode_step, sample_tokens

__all__ = [
    "ModelConfig", "ShapeConfig", "SHAPES",
    "init_lm", "forward", "make_cache", "make_paged_cache",
    "train_loss", "prefill", "decode_step", "sample_tokens",
]
