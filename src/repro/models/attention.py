"""GQA attention with RoPE/M-RoPE, optional QKV bias, prefill/decode caches.

Prefill and train use the flash kernel on TPU (chunked-jnp oracle
elsewhere); decode attends one token against a (possibly sequence-sharded)
KV cache, passing per-slot cache lengths through to the split-KV
flash-decode kernel (ops.attention with `lengths`; masked-window oracle
off-TPU) instead of materializing a dense mask.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops

from .config import ModelConfig
from .layers import apply_mrope, apply_rope, dense_init


def attn_init(key, cfg: ModelConfig):
    D, Hq, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (D, Hq * hd)),
        "wk": dense_init(ks[1], (D, Hkv * hd)),
        "wv": dense_init(ks[2], (D, Hkv * hd)),
        "wo": dense_init(ks[3], (Hq * hd, D)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((Hq * hd,), jnp.float32)
        p["bk"] = jnp.zeros((Hkv * hd,), jnp.float32)
        p["bv"] = jnp.zeros((Hkv * hd,), jnp.float32)
    return p


def _rope(cfg: ModelConfig, x, positions):
    if cfg.mrope:
        return apply_mrope(x, positions, cfg.rope_theta, cfg.mrope_sections)
    return apply_rope(x, positions, cfg.rope_theta)


def attn_fwd(
    p,
    x,                       # (B, S, D)
    positions,               # (B, S) or (B, S, 3) for mrope
    cfg: ModelConfig,
    cache: Optional[dict] = None,   # {"k","v"}: (B, S_max, Hkv, hd), or
                                    # paged pools (NB, Bs, Hkv, hd)
    cache_len=None,          # i32 scalar: valid entries in cache
    mode: str = "train",     # train | prefill | decode
    block_tables=None,       # (B, max_blocks) i32: decode against paged
                             # pools instead of a contiguous cache
) -> Tuple[jax.Array, Optional[dict]]:
    B, S, D = x.shape
    Hq, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = x.dtype

    q = x @ p["wq"].astype(dt)
    k = x @ p["wk"].astype(dt)
    v = x @ p["wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    from repro.dist.sharding import shard_act

    q = shard_act(q.reshape(B, S, Hq, hd), "batch", "seq", "heads", "none")
    k = shard_act(k.reshape(B, S, Hkv, hd), "batch", "seq", "heads", "none")
    v = shard_act(v.reshape(B, S, Hkv, hd), "batch", "seq", "heads", "none")
    q = _rope(cfg, q, positions)
    k = _rope(cfg, k, positions)

    if mode == "decode":
        assert cache is not None and S == 1
        # cache_len: scalar (whole-batch decode) or (B,) per-slot lengths
        # with -1 marking inactive serving slots (writes dropped, state
        # untouched).
        lens = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (B,))
        window = jnp.where(lens >= 0, lens + 1, 0)
        if block_tables is not None:
            # Paged cache: token at logical position lens[b] lands in
            # physical block block_tables[b, lens[b] // Bs] at offset
            # lens[b] % Bs. The scheduler guarantees that block is
            # allocated and exclusively owned (COW resolved); idle slots
            # write out-of-bounds and are dropped.
            NB, Bs = cache["k"].shape[0], cache["k"].shape[1]
            bt = jnp.asarray(block_tables, jnp.int32)
            pos = jnp.maximum(lens, 0)
            phys = jnp.take_along_axis(
                bt, (pos // Bs)[:, None], axis=1
            )[:, 0]
            phys = jnp.where(lens >= 0, phys, NB)   # OOB => dropped
            off = pos % Bs
            ck = cache["k"].at[phys, off].set(
                k[:, 0].astype(cache["k"].dtype), mode="drop"
            )
            cv = cache["v"].at[phys, off].set(
                v[:, 0].astype(cache["v"].dtype), mode="drop"
            )
            o = ops.attention(
                q, ck.astype(dt), cv.astype(dt), causal=False,
                impl=cfg.decode_impl, lengths=window, block_tables=bt,
            ).astype(dt)
            new_cache = {"k": ck, "v": cv}
        else:
            S_max = cache["k"].shape[1]
            widx = jnp.where(lens >= 0, lens, S_max)  # OOB => dropped
            brow = jnp.arange(B)
            ck = cache["k"].at[brow, widx].set(
                k[:, 0].astype(cache["k"].dtype), mode="drop"
            )
            cv = cache["v"].at[brow, widx].set(
                v[:, 0].astype(cache["v"].dtype), mode="drop"
            )
            # Cache lengths flow through as-is (no dense mask materialized
            # here): visible window = cache_len entries + the token just
            # written; idle slots (-1) get an empty window, a dead output.
            o = ops.attention(
                q, ck.astype(dt), cv.astype(dt), causal=False,
                impl=cfg.decode_impl, lengths=window,
            ).astype(dt)
            new_cache = {"k": ck, "v": cv}
    elif mode == "prefill" and block_tables is not None:
        # Chunked prefill against the paged pool: this call holds tokens
        # [start, start + S) of the sequence; KV for [0, start) already
        # sits in pool blocks (earlier chunks or a prefix-cache hit).
        # Write the chunk's k/v through the block table, then attend over
        # the whole window with causal-by-absolute-position masking
        # (kernels paged_prefill / ref.paged_prefill_ref).
        assert cache is not None
        NB, Bs = cache["k"].shape[0], cache["k"].shape[1]
        bt = jnp.asarray(block_tables, jnp.int32)
        start = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (B,))
        pos = start[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
        phys = jnp.take_along_axis(bt, pos // Bs, axis=1)        # (B, S)
        off = pos % Bs
        ck = cache["k"].at[phys, off].set(
            k.astype(cache["k"].dtype), mode="drop"
        )
        cv = cache["v"].at[phys, off].set(
            v.astype(cache["v"].dtype), mode="drop"
        )
        o = ops.attention(
            q, ck.astype(dt), cv.astype(dt), causal=True,
            impl=cfg.decode_impl, lengths=start + S, block_tables=bt,
            q_offset=start,
        ).astype(dt)
        new_cache = {"k": ck, "v": cv}
    else:
        import os

        o = ops.attention(
            q, k, v, causal=True, impl=cfg.attn_impl,
            block_q=int(os.environ.get("REPRO_ATTN_BLOCK", "512")),
        )
        new_cache = None
        if mode == "prefill" and cache is not None:
            # Write the prompt's k/v into the (larger) cache at cache_len
            # (chunk 0 in practice); prompt length S <= cache size.
            off = cache_len if cache_len is not None else jnp.int32(0)
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, off, 0, 0)
            )
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, off, 0, 0)
            )
            new_cache = {"k": ck, "v": cv}
    y = o.reshape(B, S, Hq * hd) @ p["wo"].astype(dt)
    return y, new_cache
