"""Model + shape configuration for the assigned architectures."""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    vocab: int = 32000
    head_dim: int = 0           # 0 => d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    # --- MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # --- SSM (Mamba2)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    # --- hybrid (Zamba2): one shared attention block every `attn_every`
    attn_every: int = 0
    # --- modality frontends (stubs per spec: precomputed embeddings)
    n_codebooks: int = 0        # musicgen EnCodec streams
    mrope: bool = False         # qwen2-vl multimodal rotary
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    # --- numerics / perf knobs
    dtype: str = "bfloat16"     # compute/activation dtype
    remat: str = "full"         # none | dots | full
    attn_impl: str = "auto"     # kernels.ops.attention impl (prefill/train)
    decode_impl: str = "auto"   # Sq==1 cached-decode impl (flash_decode)
    scan_layers: bool = True    # lax.scan over stacked layer params
    moe_impl: str = "auto"      # auto | global | ep (shard_map EP dispatch)

    # ------------------------------------------------------------ derived
    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def padded_vocab(self) -> int:
        # pad so TP vocab sharding divides for any model-axis <= 256, and
        # the MXU lane dim stays 128-aligned
        return int(math.ceil(self.vocab / 256) * 256)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        # long_500k decode only runs for bounded-state archs (spec).
        return self.family in ("ssm", "hybrid")

    # ------------------------------------------------- parameter counting
    def param_count(self) -> int:
        """Total parameters (N for the roofline's 6·N·D)."""
        return self._count(active_only=False)

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: top_k of n_experts)."""
        return self._count(active_only=True)

    def _count(self, active_only: bool) -> int:
        D = self.d_model
        n = 0
        # embeddings (+ output head unless tied)
        emb = self.padded_vocab * D
        n += emb * (self.n_codebooks or 1)
        if not self.tie_embeddings:
            n += self.padded_vocab * D * (self.n_codebooks or 1)
        per_layer_attn = 0
        if self.n_heads:
            per_layer_attn = (
                D * self.n_heads * self.hd          # wq
                + 2 * D * self.n_kv_heads * self.hd  # wk, wv
                + self.n_heads * self.hd * D         # wo
            )
        mlp = 3 * D * self.d_ff if self.d_ff else 0  # SwiGLU
        if self.family == "moe":
            e = self.top_k if active_only else self.n_experts
            mlp = 3 * D * self.d_ff * e + D * self.n_experts  # experts+router
        mamba = 0
        if self.family in ("ssm", "hybrid"):
            di, nh, ns = self.d_inner, self.ssm_heads, self.ssm_state
            mamba = (
                D * (2 * di + 2 * ns + nh)      # wz,wx,wB,wC,wdt projections
                + self.ssm_conv * (di + 2 * ns)  # depthwise convs (x,B,C)
                + di * D                         # out_proj
                + 2 * nh                         # A_log, D skip
            )
        if self.family == "hybrid":
            n_attn_applications = 1  # weights shared -> count once
            n += per_layer_attn * n_attn_applications + self.n_layers * (
                mamba + 2 * D
            ) + self.n_layers * (3 * D * self.d_ff if self.d_ff else 0)
        elif self.family == "ssm":
            n += self.n_layers * (mamba + D)
        else:
            n += self.n_layers * (per_layer_attn + mlp + 2 * D)
        return n

    # ------------------------------------------------------ smoke variant
    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        hd2 = 16 // 2  # reduced head_dim of 16
        s1 = hd2 // 4
        s2 = (hd2 - s1 + 1) // 2
        return dataclasses.replace(
            self,
            mrope_sections=(s1, s2, hd2 - s1 - s2),
            name=self.name + "-smoke",
            n_layers=2,
            d_model=64,
            n_heads=4 if self.n_heads else 0,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            head_dim=16 if self.n_heads else 0,
            d_ff=128 if self.d_ff else 0,
            vocab=512,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            ssm_state=16 if self.ssm_state else 0,
            ssm_headdim=16 if self.ssm_state else 64,
            attn_every=2 if self.attn_every else 0,
            dtype="float32",
            remat="none",
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    def smoke(self) -> "ShapeConfig":
        return ShapeConfig(self.name + "-smoke", min(self.seq_len, 64),
                           min(self.global_batch, 2), self.kind)


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
