"""GLB-MoE: the paper's lifeline load-balancing applied to expert parallelism.

MoE routing load is irregular and unpredictable — the same problem the paper
solves for task bags. Here the "task items" are expert shards: each EP rank
owns E/R expert slots; the observed per-expert token counts (returned by
``moe_fwd`` every step) are the workload signal.

Between steps (infrequent, host-side) we run the SAME deterministic matching
as the task scheduler (`core.lifeline.match_steals`) on per-rank loads:
underloaded ranks are "hungry thieves", overloaded ranks are victims, and a
matched steal swaps the victim's hottest expert with the thief's coldest
expert (a swap keeps slot counts static, which keeps shapes/shardings
static). Logical-expert -> physical-slot indirection (`perm`) makes the swap
a pure weight permutation: the math is bit-identical, only placement moves.

This is DeepSeek-EPLB-style expert placement balancing, derived from the
paper's observe-imbalance -> steal loop; see DESIGN.md §4.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import GLBParams, lifeline_buddies, match_steals


class RebalanceResult(NamedTuple):
    perm: np.ndarray          # (E,) logical expert -> physical slot
    loads_before: np.ndarray  # (R,)
    loads_after: np.ndarray   # (R,)
    swaps: list               # [(rank_victim, rank_thief, slot_a, slot_b)]


def _rank_loads(counts, perm, n_ranks):
    E = counts.shape[0]
    per = E // n_ranks
    slot_load = np.zeros(E)
    slot_load[perm] = counts          # load of the slot hosting each expert
    return slot_load.reshape(n_ranks, per).sum(axis=1), slot_load


def glb_expert_rebalance(
    counts,                    # (E,) tokens routed to each *logical* expert
    perm,                      # (E,) current logical->slot map
    n_ranks: int,
    rounds: int = 8,
    hunger: float = 0.9,       # hungry if load < hunger * mean
    seed: int = 0,
) -> RebalanceResult:
    counts = np.asarray(counts, np.float64)
    perm = np.asarray(perm, np.int64).copy()
    E = counts.shape[0]
    assert E % n_ranks == 0
    per = E // n_ranks
    params = GLBParams(w=2)
    z = params.resolve_z(n_ranks)
    buddies = jnp.asarray(lifeline_buddies(n_ranks, z))
    pending = jnp.zeros((n_ranks, n_ranks), bool)
    loads0, _ = _rank_loads(counts, perm, n_ranks)
    swaps = []

    for r in range(rounds):
        loads, slot_load = _rank_loads(counts, perm, n_ranks)
        mean = loads.mean()
        hungry = loads < hunger * mean
        if not hungry.any():
            break
        # surplus (integerized) is the "bag size": only above-mean ranks give
        sizes = np.maximum(loads - mean, 0).astype(np.int32)
        m = match_steals(
            jnp.asarray(sizes), jnp.asarray(hungry), pending,
            jax.random.fold_in(jax.random.key(seed), r), buddies, params,
        )
        pending = m.pending
        src = np.asarray(m.src)
        did = False
        for thief in range(n_ranks):
            victim = int(src[thief])
            if victim < 0:
                continue
            # swap victim's hottest expert with thief's coldest
            v_slots = np.arange(victim * per, (victim + 1) * per)
            t_slots = np.arange(thief * per, (thief + 1) * per)
            hot = v_slots[np.argmax(slot_load[v_slots])]
            cold = t_slots[np.argmin(slot_load[t_slots])]
            gain = slot_load[hot] - slot_load[cold]
            if gain <= 0:
                continue
            # apply only if it improves the pairwise imbalance
            if loads[victim] - loads[thief] > gain * 0.5:
                e_hot = int(np.nonzero(perm == hot)[0][0])
                e_cold = int(np.nonzero(perm == cold)[0][0])
                perm[e_hot], perm[e_cold] = cold, hot
                slot_load[hot], slot_load[cold] = slot_load[cold], slot_load[hot]
                loads, _ = _rank_loads(counts, perm, n_ranks)
                swaps.append((victim, thief, int(hot), int(cold)))
                did = True
        if not did and not bool(np.asarray(m.pending).any()):
            break

    loads1, _ = _rank_loads(counts, perm, n_ranks)
    return RebalanceResult(perm=perm, loads_before=loads0, loads_after=loads1,
                           swaps=swaps)


def permute_expert_params(moe_params: dict, perm_old, perm_new) -> dict:
    """Physically move expert weights so logical expert e sits at slot
    perm_new[e]. Pure gather on the leading expert axis (cross-rank
    collective when EP-sharded; runs rarely). Router stays logical."""
    perm_old = np.asarray(perm_old)
    perm_new = np.asarray(perm_new)
    E = perm_old.shape[0]
    # w_new[perm_new[e]] = w_old[perm_old[e]]  =>  gather index per new slot
    gather = np.empty(E, np.int64)
    gather[perm_new] = perm_old
    gidx = jnp.asarray(gather)
    out = dict(moe_params)
    for k in ("wg", "wi", "wo"):
        out[k] = moe_params[k][gidx]
    return out
