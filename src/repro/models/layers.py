"""Shared neural building blocks (pure jnp, params are plain dict pytrees)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dense_init(key, shape, in_axis: int = 0):
    """Truncated-normal fan-in init, stored float32 (master weights)."""
    fan_in = shape[in_axis] if isinstance(in_axis, int) else int(
        np.prod([shape[a] for a in in_axis])
    )
    std = 1.0 / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2, 2, shape, jnp.float32) * std)


def embed_init(key, shape):
    return jax.random.truncated_normal(key, -2, 2, shape, jnp.float32) * 0.02


def rmsnorm(x, scale, eps: float):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dt)


# ------------------------------------------------------------------ RoPE
def rope_freqs(hd: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, hd, 2, dtype=np.float64) / hd))


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, D); positions: (B, S) absolute int positions."""
    B, S, H, D = x.shape
    freqs = jnp.asarray(rope_freqs(D, theta), jnp.float32)      # (D/2,)
    ang = positions.astype(jnp.float32)[..., None] * freqs      # (B, S, D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions, theta: float, sections):
    """Qwen2-VL multimodal RoPE: positions (B, S, 3) = (temporal, h, w);
    the D/2 frequency lanes are split into `sections` (sum = D/2), each
    rotated by its own position stream."""
    B, S, H, D = x.shape
    assert sum(sections) == D // 2, (sections, D)
    freqs = jnp.asarray(rope_freqs(D, theta), jnp.float32)      # (D/2,)
    # pick the position stream per frequency lane
    sec_id = np.concatenate(
        [np.full(s, i) for i, s in enumerate(sections)]
    )                                                            # (D/2,)
    pos = jnp.take_along_axis(
        positions.astype(jnp.float32),                           # (B, S, 3)
        jnp.asarray(sec_id, jnp.int32)[None, None, :].repeat(S, 1).repeat(B, 0),
        axis=-1,
    )                                                            # (B, S, D/2)
    ang = pos * freqs
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------ MLP
def mlp_init(key, d_model: int, d_ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wg": dense_init(k1, (d_model, d_ff)),
        "wi": dense_init(k2, (d_model, d_ff)),
        "wo": dense_init(k3, (d_ff, d_model)),
    }


def mlp_fwd(p, x, dtype):
    g = jax.nn.silu(x @ p["wg"].astype(dtype))
    h = x @ p["wi"].astype(dtype)
    return (g * h) @ p["wo"].astype(dtype)
