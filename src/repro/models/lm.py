"""Task-level entry points: training loss, prefill, decode."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .transformer import forward, make_cache

AUX_LOSS_WEIGHT = 0.01


def _xent(logits, targets, vocab: int):
    """Stable CE on the unpadded vocab slice; logits (..., Vp) f32 math."""
    lg = logits[..., :vocab].astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, targets[..., None], axis=-1)[..., 0]
    return lse - gold


def train_loss(params, cfg: ModelConfig, batch: Dict[str, Any]):
    """batch: {"tokens": (B,S[,K])} or vlm {"embeds","positions","labels"}."""
    if cfg.family == "vlm":
        logits, _, aux = forward(
            params, cfg, embeds=batch["embeds"],
            positions=batch.get("positions"), mode="train",
        )
        targets = batch["labels"][:, 1:]
        per_tok = _xent(logits[:, :-1], targets, cfg.vocab)
    elif cfg.n_codebooks:
        tokens = batch["tokens"]                      # (B,S,K)
        logits, _, aux = forward(params, cfg, tokens=tokens, mode="train")
        per_tok = _xent(logits[:, :-1], tokens[:, 1:], cfg.vocab).mean(-1)
    else:
        tokens = batch["tokens"]                      # (B,S)
        logits, _, aux = forward(params, cfg, tokens=tokens, mode="train")
        per_tok = _xent(logits[:, :-1], tokens[:, 1:], cfg.vocab)
    loss = per_tok.mean()
    metrics = {"ce_loss": loss}
    if cfg.family == "moe":
        aux_l = aux["aux_loss"] / cfg.n_layers
        loss = loss + AUX_LOSS_WEIGHT * aux_l
        metrics["aux_loss"] = aux_l
        metrics["expert_counts"] = aux["expert_counts"]
        metrics["dropped_frac"] = aux["dropped"] / (
            jnp.float32(per_tok.size) * cfg.top_k * cfg.n_layers
        )
    metrics["loss"] = loss
    return loss, metrics


def prefill(params, cfg: ModelConfig, batch: Dict[str, Any], max_seq: int):
    """Fill a fresh cache of size max_seq; prompt must be padded to max_seq.
    Returns (logits_last, cache)."""
    tokens = batch.get("tokens")
    embeds = batch.get("embeds")
    B = (tokens if tokens is not None else embeds).shape[0]
    cache = make_cache(cfg, B, max_seq)
    logits, cache, _ = forward(
        params, cfg, tokens=tokens, embeds=embeds,
        positions=batch.get("positions"), cache=cache, cache_len=jnp.int32(0),
        mode="prefill",
    )
    return logits, cache


def decode_step(params, cfg: ModelConfig, tokens, cache, cache_len,
                block_tables=None):
    """One token per sequence: tokens (B,1[,K]). Returns (logits, cache).
    With ``block_tables`` the cache is the paged block pool
    (make_paged_cache) instead of contiguous per-slot rows."""
    logits, cache, _ = forward(
        params, cfg, tokens=tokens, cache=cache, cache_len=cache_len,
        mode="decode", block_tables=block_tables,
    )
    return logits, cache


def sample_tokens(logits, key, temperature: float = 0.0):
    """On-device sampling over already-vocab-sliced logits (..., V):
    greedy argmax at temperature 0, else categorical at logits/T. The
    temperature is a trace-time constant, so jitted callers bake the
    branch in. Returns i32 token ids shaped like logits[..., 0]."""
    lg = logits.astype(jnp.float32)
    if temperature <= 0.0:
        return jnp.argmax(lg, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, lg / temperature, axis=-1).astype(
        jnp.int32
    )
