"""Mamba2 (SSD) block: per-component projections [z | x | B | C | dt],
causal depthwise conv over x/B/C, selective state-space scan
(kernels.ops.ssd), gated RMSNorm, out_proj. Decode carries (conv_state,
ssm_state) instead of a KV cache — O(1) per token, which is why the
ssm/hybrid archs own long_500k.

Projections (and convs) are SEPARATE per component rather than one fused
in_proj: the fused layout's output (2*di + 2*ns + nh channels) is not
divisible by the TP mesh axis and its split boundaries cut across shards,
which made GSPMD emit a collective-permute per slice (≈1.3 GB/layer on
mamba2-130m train_4k — EXPERIMENTS §Perf S1). Per-component tensors shard
cleanly (x,z: d_inner % 16 == 0; B,C,dt replicated: tiny) — same math,
identical parameter count.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops

from .config import ModelConfig
from .layers import dense_init, rmsnorm


def mamba_init(key, cfg: ModelConfig):
    D, di, ns, nh = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    ks = jax.random.split(key, 9)
    return {
        "wz": dense_init(ks[0], (D, di)),
        "wx": dense_init(ks[1], (D, di)),
        "wB": dense_init(ks[2], (D, ns)),
        "wC": dense_init(ks[3], (D, ns)),
        "wdt": dense_init(ks[4], (D, nh)),
        "conv_x": dense_init(ks[5], (cfg.ssm_conv, di)) * 0.5,
        "conv_B": dense_init(ks[6], (cfg.ssm_conv, ns)) * 0.5,
        "conv_C": dense_init(ks[7], (cfg.ssm_conv, ns)) * 0.5,
        "A_log": jnp.zeros((nh,), jnp.float32),        # A = -exp(A_log) = -1
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "gate_norm": jnp.zeros((di,), jnp.float32),
        "out_proj": dense_init(ks[8], (di, D)),
    }


def _causal_conv(xc, w, conv_state=None):
    """Depthwise causal conv along seq. xc (B,S,C); w (K,C).
    conv_state: (B, K-1, C) trailing inputs from the previous step."""
    K = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros_like(xc[:, : K - 1])
    else:
        pad = conv_state.astype(xc.dtype)
    full = jnp.concatenate([pad, xc], axis=1)          # (B, S+K-1, C)
    out = sum(
        full[:, i : i + xc.shape[1]] * w[i][None, None, :] for i in range(K)
    )
    new_state = full[:, -(K - 1):]                      # (B, K-1, C)
    return jax.nn.silu(out), new_state


CONV_KEYS = ("conv_x", "conv_B", "conv_C")


def mamba_fwd(
    p,
    x,                      # (B, S, D)
    cfg: ModelConfig,
    cache: Optional[dict] = None,   # {conv_x/conv_B/conv_C: (B,K-1,*),
                                    #  ssm: (B,H,N,P)}
    mode: str = "train",
    active=None,            # (B,) bool — serving slots whose state may move
) -> Tuple[jax.Array, Optional[dict]]:
    B, S, D = x.shape
    di, ns, nh, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    dt_ = x.dtype

    z = x @ p["wz"].astype(dt_)
    xi = x @ p["wx"].astype(dt_)
    Bm = x @ p["wB"].astype(dt_)
    Cm = x @ p["wC"].astype(dt_)
    dt = x @ p["wdt"].astype(dt_)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,nh)
    A = -jnp.exp(p["A_log"])                                     # (nh,)

    states = {k: (cache.get(k) if cache else None) for k in CONV_KEYS}
    xi, new_cx = _causal_conv(xi, p["conv_x"].astype(dt_), states["conv_x"])
    Bm, new_cb = _causal_conv(Bm, p["conv_B"].astype(dt_), states["conv_B"])
    Cm, new_cc = _causal_conv(Cm, p["conv_C"].astype(dt_), states["conv_C"])
    Bm = Bm.astype(jnp.float32)
    Cm = Cm.astype(jnp.float32)

    xh = xi.reshape(B, S, nh, hp)
    if mode == "decode":
        # single-step recurrence on the carried state; inactive serving
        # slots (active=False) keep their state untouched
        h0 = cache["ssm"].astype(jnp.float32)           # (B, nh, ns, hp)
        a = jnp.exp(A[None, :] * dt[:, 0])              # (B, nh)
        dx = dt[:, 0, :, None] * xh[:, 0].astype(jnp.float32)
        upd = Bm[:, 0, None, :, None] * dx[:, :, None, :]
        h = h0 * a[:, :, None, None] + upd
        y = jnp.einsum("bn,bhnp->bhp", Cm[:, 0], h)[:, None]  # (B,1,nh,hp)
        new_ssm = h
        if active is not None:
            act = active.reshape(B, 1, 1, 1)
            new_ssm = jnp.where(act, new_ssm, h0)
            a3 = active.reshape(B, 1, 1)
            olds = {
                k: (states[k] if states[k] is not None else z_)
                for k, z_ in (("conv_x", jnp.zeros_like(new_cx)),
                              ("conv_B", jnp.zeros_like(new_cb)),
                              ("conv_C", jnp.zeros_like(new_cc)))
            }
            new_cx = jnp.where(a3, new_cx, olds["conv_x"].astype(new_cx.dtype))
            new_cb = jnp.where(a3, new_cb, olds["conv_B"].astype(new_cb.dtype))
            new_cc = jnp.where(a3, new_cc, olds["conv_C"].astype(new_cc.dtype))
    else:
        y, new_ssm = ops.ssd(xh, dt, A, Bm, Cm, impl=cfg.attn_impl
                             if cfg.attn_impl != "auto" else "auto")
    y = y.astype(dt_) + xh * p["D"].astype(dt_)[None, None, :, None]
    y = y.reshape(B, S, di)
    # gated RMSNorm (mamba2): norm(y) * silu(z)
    y = rmsnorm(y, p["gate_norm"], cfg.norm_eps) * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(dt_)
    new_cache = None
    if mode in ("prefill", "decode"):
        ref_dt = (cache["conv_x"].dtype if cache else jnp.bfloat16)
        new_cache = {
            "conv_x": new_cx.astype(ref_dt),
            "conv_B": new_cb.astype(ref_dt),
            "conv_C": new_cc.astype(ref_dt),
            "ssm": (new_ssm if cache is None
                    else new_ssm.astype(cache["ssm"].dtype)),
        }
    return out, new_cache
