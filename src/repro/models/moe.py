"""Mixture-of-Experts layer: top-k router, capacity-bounded dispatch, aux
load-balancing loss — plus the paper's technique applied to experts:
a GLB-style expert-placement rebalancer (see glb_moe.py) that migrates /
swaps experts between EP ranks based on observed load, exactly the paper's
"observe imbalance -> steal work" loop at the granularity of expert shards.

Dispatch is einsum-based (one-hot combine/dispatch tensors), the standard
TPU-friendly formulation; the expert axis is sharded over the `model` mesh
axis (EP).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init


def moe_init(key, cfg: ModelConfig):
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (D, E)),
        "wg": dense_init(ks[1], (E, D, F), in_axis=1),
        "wi": dense_init(ks[2], (E, D, F), in_axis=1),
        "wo": dense_init(ks[3], (E, F, D), in_axis=1),
    }


def moe_fwd(p, x, cfg: ModelConfig, expert_perm=None) -> Tuple[jax.Array, dict]:
    """x: (B, S, D) -> (y, aux). aux carries the load-balancing loss term and
    per-expert token counts (the GLB rebalancer's input signal).

    expert_perm: optional (E,) i32 permutation from the GLB expert-placement
    rebalancer; logically expert e's weights live at slot expert_perm[e].

    Dispatch impls (cfg.moe_impl):
      global — single global-view scatter/gather (reference semantics; GSPMD
               replicates the expert buffers at scale — see EXPERIMENTS §Perf)
      ep     — shard_map expert parallelism: activations are replicated over
               `model`, so each model-rank dispatches ONLY to its E/ranks
               local experts and the combine is one psum; collective traffic
               is one (B_loc,S,D) all-reduce per layer instead of replicated
               (E,cap,D) buffers.
      auto   — ep when an ambient mesh with a `model` axis exists.

    ep differences vs global (both tested): capacity truncation happens per
    DP shard; the aux loss is the per-shard Switch estimator (pmean of
    fe_local·me_local), standard in EP frameworks."""
    mesh = None
    if cfg.moe_impl in ("auto", "ep"):
        try:
            m = jax.sharding.get_abstract_mesh()
            if (m is not None and "model" in m.shape
                    and cfg.n_experts % m.shape["model"] == 0):
                mesh = m
        except Exception:  # noqa: BLE001
            mesh = None
        if cfg.moe_impl == "ep" and mesh is None:
            raise ValueError("moe_impl='ep' needs an ambient mesh with a "
                             "'model' axis dividing n_experts")
    if mesh is not None:
        return _moe_fwd_ep(p, x, cfg, expert_perm, mesh)
    return _moe_fwd_global(p, x, cfg, expert_perm)


def _moe_fwd_global(p, x, cfg: ModelConfig, expert_perm=None):
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    dt = x.dtype
    xt = x.reshape(T, D)

    logits = (xt @ p["router"].astype(dt)).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)                # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9
    )
    if expert_perm is not None:
        gate_idx = expert_perm[gate_idx]

    # aux load-balance loss (Switch-style): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)                                  # (E,)
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)       # (T, K, E)
    fe = onehot.sum(axis=(0, 1)) / (T * K)
    aux_loss = E * jnp.sum(fe * me)

    # capacity-bounded dispatch, scatter/gather form: no (T,E,cap)
    # intermediates, so it scales to millions of global tokens under pjit
    cap = int(max(1, round(T * K / E * cfg.capacity_factor)))
    flat = onehot.reshape(T * K, E)
    pos = (jnp.cumsum(flat, axis=0) - flat).reshape(T, K, E)      # queue pos
    keep = (pos < cap) * onehot                                    # (T, K, E)
    pos_in = (pos * keep).sum(-1).astype(jnp.int32)                # (T, K)
    kept = keep.sum(-1)                                            # (T, K)

    from repro.dist.sharding import shard_act

    # scatter tokens into expert slot buffers; dropped rows hit the
    # sentinel expert row E (sliced off afterwards)
    xe = jnp.zeros((E + 1, cap, D), dt)
    for kk in range(K):  # K is small and static
        e_k = jnp.where(kept[:, kk] > 0, gate_idx[:, kk], E).astype(jnp.int32)
        xe = xe.at[e_k, pos_in[:, kk]].add(xt)
    xe = shard_act(xe[:E], "expert", "batch", "none")              # EP slots

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["wg"].astype(dt)))
    h = h * jnp.einsum("ecd,edf->ecf", xe, p["wi"].astype(dt))
    ye = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(dt))          # (E,cap,D)
    ye = jnp.concatenate([ye, jnp.zeros((1, cap, D), dt)], axis=0)

    # combine: gather each (t,k) slot back, weighted by its gate
    y = jnp.zeros((T, D), dt)
    for kk in range(K):
        e_k = jnp.where(kept[:, kk] > 0, gate_idx[:, kk], E).astype(jnp.int32)
        y = y + ye[e_k, pos_in[:, kk]] * gate_vals[:, kk, None].astype(dt)
    y = y.reshape(B, S, D)

    counts = onehot.sum(axis=(0, 1))                                # (E,)
    dropped = (1.0 - kept).sum()
    return y, {"aux_loss": aux_loss, "expert_counts": counts,
               "dropped": dropped, "capacity": cap}


def _rank_within_expert(gate_idx_flat, E: int):
    """Queue position of each routed (t,k) slot within its expert, via a
    stable sort — O(T·K) vectors instead of the (T·K, E) dense cumsum
    (EXPERIMENTS §Perf iteration 2: the routing-buffer bytes dominated)."""
    n = gate_idx_flat.shape[0]
    order = jnp.argsort(gate_idx_flat, stable=True)
    sorted_e = gate_idx_flat[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(E, dtype=sorted_e.dtype))
    rank_sorted = jnp.arange(n, dtype=jnp.int32) - starts[sorted_e]
    pos = jnp.zeros((n,), jnp.int32).at[order].set(rank_sorted)
    counts = jnp.bincount(gate_idx_flat, length=E)
    return pos, counts


def _moe_fwd_ep(p, x, cfg: ModelConfig, expert_perm, mesh):
    """shard_map EP dispatch; see moe_fwd docstring. Math matches the
    global impl up to per-DP-shard (vs global) capacity truncation and
    dispatch-queue order (sort-based ranking)."""
    from jax.sharding import PartitionSpec as P

    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    dt = x.dtype
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    n_ranks = mesh.shape["model"]
    E_loc = E // n_ranks
    perm = (jnp.arange(E, dtype=jnp.int32) if expert_perm is None
            else jnp.asarray(expert_perm, jnp.int32))

    def inner(router, wg, wi, wo, perm_, xl):
        Bl, Sl, _ = xl.shape
        Tl = Bl * Sl
        xt = xl.reshape(Tl, D)
        logits = (xt @ router.astype(dt)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, gate_idx = jax.lax.top_k(probs, K)
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9)
        gate_idx = perm_[gate_idx]

        # routing stats from (T·K,) vectors — no (T,K,E) one-hots
        flat_e = gate_idx.reshape(Tl * K)
        pos_flat, counts_local = _rank_within_expert(flat_e, E)
        me = jnp.mean(probs, axis=0)
        fe = counts_local.astype(jnp.float32) / (Tl * K)
        aux_local = E * jnp.sum(fe * me)
        counts_local = counts_local.astype(jnp.float32)

        cap = int(max(1, round(Tl * K / E * cfg.capacity_factor)))
        pos_in = pos_flat.reshape(Tl, K)
        kept = (pos_in < cap).astype(jnp.float32)

        # local dispatch: only my E_loc experts; everything else -> sentinel
        lo = jax.lax.axis_index("model").astype(jnp.int32) * E_loc
        xe = jnp.zeros((E_loc + 1, cap, D), dt)
        rels = []
        for kk in range(K):
            rel = gate_idx[:, kk] - lo
            ok = (kept[:, kk] > 0) & (rel >= 0) & (rel < E_loc)
            rel = jnp.where(ok, rel, E_loc)
            rels.append(rel)
            xe = xe.at[rel, pos_in[:, kk]].add(xt)

        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe[:E_loc],
                                   wg.astype(dt)))
        h = h * jnp.einsum("ecd,edf->ecf", xe[:E_loc], wi.astype(dt))
        ye = jnp.einsum("ecf,efd->ecd", h, wo.astype(dt))
        ye = jnp.concatenate([ye, jnp.zeros((1, cap, D), dt)], axis=0)

        y = jnp.zeros((Tl, D), dt)
        for kk in range(K):
            y = y + ye[rels[kk], pos_in[:, kk]] * gate_vals[:, kk, None].astype(dt)
        # each token's experts live on exactly one rank each -> psum = combine
        y = jax.lax.psum(y, "model").reshape(Bl, Sl, D)

        if dp:
            aux_local = jax.lax.pmean(aux_local, dp)
            counts_local = jax.lax.psum(counts_local, dp)
            drop = jax.lax.psum((1.0 - kept).sum(), dp)
        else:
            drop = (1.0 - kept).sum()
        return y, aux_local, counts_local, drop

    y, aux_loss, counts, dropped = jax.shard_map(
        inner,
        mesh=mesh,
        in_specs=(
            P(None, None),                 # router, gathered/replicated
            P("model", None, None),        # wg  (EP on the expert axis)
            P("model", None, None),        # wi
            P("model", None, None),        # wo
            P(None),                       # perm
            P(dp if dp else None, None, None),  # x: DP over batch
        ),
        out_specs=(P(dp if dp else None, None, None), P(), P(), P()),
        check_vma=False,
    )(p["router"], p["wg"], p["wi"], p["wo"], perm, x)
    cap = int(max(1, round(B * S * K / E * cfg.capacity_factor)))
    return y, {"aux_loss": aux_loss, "expert_counts": counts,
               "dropped": dropped, "capacity": cap}
