"""Decoder-only LM assembly for all assigned families.

Families compose from blocks:
  dense/vlm/audio : [rmsnorm -> GQA attn -> rmsnorm -> SwiGLU] x L
  moe             : [rmsnorm -> GQA attn -> rmsnorm -> MoE] x L
  ssm             : [rmsnorm -> Mamba2] x L
  hybrid (zamba2) : [rmsnorm -> Mamba2] x L, plus ONE weight-shared
                    (attn + MLP) block applied every `attn_every` layers
                    (Zamba2's shared-block weight tying)

Layers are scanned (stacked params, O(1) HLO in depth — compile time matters
at 512 devices) with a configurable remat policy. Params are stored float32
(master copies); compute casts to cfg.dtype.

Modality frontends are stubs per spec: musicgen consumes EnCodec token
streams (B,S,K) with K embedding tables + K output heads; qwen2-vl consumes
precomputed merged embeddings (B,S,D) plus M-RoPE positions (B,S,3).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from .attention import attn_fwd, attn_init
from .config import ModelConfig
from .layers import embed_init, mlp_fwd, mlp_init, rmsnorm
from .mamba2 import mamba_fwd, mamba_init
from .moe import moe_fwd, moe_init


# ------------------------------------------------------------------ init
def _block_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    if cfg.family == "ssm":
        return {"ln": jnp.zeros((cfg.d_model,)), "mamba": mamba_init(ks[0], cfg)}
    if cfg.family == "hybrid":
        return {"ln": jnp.zeros((cfg.d_model,)), "mamba": mamba_init(ks[0], cfg)}
    blk = {
        "ln1": jnp.zeros((cfg.d_model,)),
        "attn": attn_init(ks[0], cfg),
        "ln2": jnp.zeros((cfg.d_model,)),
    }
    if cfg.family == "moe":
        blk["moe"] = moe_init(ks[1], cfg)
    else:
        blk["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff)
    return blk


def init_lm(key, cfg: ModelConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, 8)
    Vp, D = cfg.padded_vocab, cfg.d_model
    params: Dict[str, Any] = {}
    if cfg.n_codebooks:
        params["embed"] = embed_init(ks[0], (cfg.n_codebooks, Vp, D))
        if not cfg.tie_embeddings:
            params["head"] = embed_init(ks[1], (cfg.n_codebooks, D, Vp))
    else:
        params["embed"] = embed_init(ks[0], (Vp, D))
        if not cfg.tie_embeddings:
            params["head"] = embed_init(ks[1], (D, Vp))
    layer_keys = jax.random.split(ks[2], cfg.n_layers)
    params["blocks"] = jax.vmap(lambda k: _block_init(k, cfg))(layer_keys)
    if cfg.family == "hybrid":
        params["shared"] = {
            "ln1": jnp.zeros((D,)),
            "attn": attn_init(ks[3], cfg),
            "ln2": jnp.zeros((D,)),
            "mlp": mlp_init(ks[4], D, cfg.d_ff),
        }
    params["final_ln"] = jnp.zeros((D,))
    return params


# ----------------------------------------------------------------- cache
def n_attn_caches(cfg: ModelConfig) -> int:
    if cfg.family == "ssm":
        return 0
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.attn_every
    return cfg.n_layers


def make_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> Dict[str, Any]:
    cache: Dict[str, Any] = {}
    na = n_attn_caches(cfg)
    if na:
        kv = (na, batch, max_seq, cfg.n_kv_heads, cfg.hd)
        cache["k"] = jnp.zeros(kv, dtype)
        cache["v"] = jnp.zeros(kv, dtype)
    if cfg.family in ("ssm", "hybrid"):
        L = cfg.n_layers
        k1 = cfg.ssm_conv - 1
        cache["conv_x"] = jnp.zeros((L, batch, k1, cfg.d_inner), dtype)
        cache["conv_B"] = jnp.zeros((L, batch, k1, cfg.ssm_state), dtype)
        cache["conv_C"] = jnp.zeros((L, batch, k1, cfg.ssm_state), dtype)
        cache["ssm"] = jnp.zeros(
            (L, batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_headdim),
            jnp.float32,
        )
    return cache


def make_paged_cache(cfg: ModelConfig, num_blocks: int, block_size: int,
                     slots: int, dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Paged serving cache: attention k/v are flat per-layer *pools* of
    ``num_blocks`` blocks of ``block_size`` tokens, shared by every
    sequence and indirected through per-sequence block tables
    (serve/kvpool.py owns the mapping). Recurrent conv/ssm state is O(1)
    per sequence, so it stays dense per decode slot."""
    cache: Dict[str, Any] = {}
    na = n_attn_caches(cfg)
    if na:
        kv = (na, num_blocks, block_size, cfg.n_kv_heads, cfg.hd)
        cache["k"] = jnp.zeros(kv, dtype)
        cache["v"] = jnp.zeros(kv, dtype)
    if cfg.family in ("ssm", "hybrid"):
        L = cfg.n_layers
        k1 = cfg.ssm_conv - 1
        cache["conv_x"] = jnp.zeros((L, slots, k1, cfg.d_inner), dtype)
        cache["conv_B"] = jnp.zeros((L, slots, k1, cfg.ssm_state), dtype)
        cache["conv_C"] = jnp.zeros((L, slots, k1, cfg.ssm_state), dtype)
        cache["ssm"] = jnp.zeros(
            (L, slots, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_headdim),
            jnp.float32,
        )
    return cache


def _slice_cache(cache, keys, idx):
    return {
        k.split("/")[-1]: jax.lax.dynamic_index_in_dim(cache[k], idx, 0, False)
        for k in keys
    }


def _update_cache(cache, keys, idx, new):
    out = dict(cache)
    for k in keys:
        leaf = new[k.split("/")[-1]]
        out[k] = jax.lax.dynamic_update_index_in_dim(
            cache[k], leaf.astype(cache[k].dtype), idx, 0
        )
    return out


# --------------------------------------------------------------- blocks
def _apply_shared_block(cfg, sp, x, positions, cache, app_idx, cache_len,
                        mode, block_tables=None):
    """Zamba2's weight-shared attention+MLP block."""
    h, new_kv = attn_fwd(
        sp["attn"], rmsnorm(x, sp["ln1"], cfg.norm_eps), positions, cfg,
        cache=None if not cache else _slice_cache(cache, ("k", "v"), app_idx),
        cache_len=cache_len, mode=mode, block_tables=block_tables,
    )
    x = x + h
    x = x + mlp_fwd(sp["mlp"], rmsnorm(x, sp["ln2"], cfg.norm_eps), x.dtype)
    if cache and new_kv is not None:
        cache = _update_cache(cache, ("k", "v"), app_idx, new_kv)
    return x, cache


def _apply_block(cfg, bp, shared, li, x, positions, cache, cache_len, mode,
                 block_tables=None):
    """One scanned layer. Returns (x, cache, aux)."""
    aux = _zero_aux(cfg)
    active = None
    if mode == "decode" and cache_len is not None:
        cl = jnp.asarray(cache_len)
        if cl.ndim == 1:
            active = cl >= 0
    if cfg.family in ("ssm", "hybrid"):
        mcache = (
            _slice_cache(cache, ("conv_x", "conv_B", "conv_C", "ssm"), li) if cache else None
        )
        h, new_m = mamba_fwd(
            bp["mamba"], rmsnorm(x, bp["ln"], cfg.norm_eps), cfg,
            cache=mcache, mode=mode, active=active,
        )
        x = x + h
        if cache and new_m is not None:
            cache = _update_cache(cache, ("conv_x", "conv_B", "conv_C", "ssm"), li, new_m)
        if cfg.family == "hybrid":
            is_app = (li + 1) % cfg.attn_every == 0
            app_idx = (li + 1) // cfg.attn_every - 1

            def yes(args):
                x, cache = args
                return _apply_shared_block(
                    cfg, shared, x, positions, cache, app_idx, cache_len,
                    mode, block_tables
                )

            x, cache = jax.lax.cond(is_app, yes, lambda a: a, (x, cache))
        return x, cache, aux

    acache = _slice_cache(cache, ("k", "v"), li) if cache else None
    h, new_kv = attn_fwd(
        bp["attn"], rmsnorm(x, bp["ln1"], cfg.norm_eps), positions, cfg,
        cache=acache, cache_len=cache_len, mode=mode,
        block_tables=block_tables,
    )
    x = x + h
    hin = rmsnorm(x, bp["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        h, moe_aux = moe_fwd(bp["moe"], hin, cfg)
        aux = {"aux_loss": moe_aux["aux_loss"],
               "expert_counts": moe_aux["expert_counts"],
               "dropped": moe_aux["dropped"]}
    else:
        h = mlp_fwd(bp["mlp"], hin, x.dtype)
    x = x + h
    if cache and new_kv is not None:
        cache = _update_cache(cache, ("k", "v"), li, new_kv)
    return x, cache, aux


def _zero_aux(cfg: ModelConfig):
    if cfg.family == "moe":
        return {
            "aux_loss": jnp.zeros((), jnp.float32),
            "expert_counts": jnp.zeros((cfg.n_experts,), jnp.float32),
            "dropped": jnp.zeros((), jnp.float32),
        }
    return {"aux_loss": jnp.zeros((), jnp.float32)}


# -------------------------------------------------------------- forward
def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        pol = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)  # "full": save nothing


def run_layers(params, cfg: ModelConfig, x, positions, cache, cache_len,
               mode, block_tables=None):
    from repro.dist.sharding import shard_act

    shared = params.get("shared")

    def body(carry, xs):
        x, cache, aux_acc = carry
        bp, li = xs
        x, cache, aux = _apply_block(
            cfg, bp, shared, li, x, positions, cache, cache_len, mode,
            block_tables
        )
        x = shard_act(x, "batch", "seq", "act_embed")
        aux_acc = jax.tree.map(lambda a, b: a + b, aux_acc, aux)
        return (x, cache, aux_acc), None

    body = _remat(body, cfg)
    idxs = jnp.arange(cfg.n_layers, dtype=jnp.int32)
    cache = cache if cache else {}
    if cfg.scan_layers:
        (x, cache, aux), _ = jax.lax.scan(
            body, (x, cache, _zero_aux(cfg)), (params["blocks"], idxs)
        )
    else:
        carry = (x, cache, _zero_aux(cfg))
        for i in range(cfg.n_layers):
            bp = jax.tree.map(lambda a: a[i], params["blocks"])
            carry, _ = body(carry, (bp, idxs[i]))
        x, cache, aux = carry
    return x, cache, aux


def embed_tokens(params, cfg: ModelConfig, tokens):
    dt = jnp.dtype(cfg.dtype)
    if cfg.n_codebooks:
        # musicgen: sum the K codebook embeddings (B,S,K) -> (B,S,D)
        embs = params["embed"].astype(dt)          # (K, Vp, D)
        x = sum(
            embs[k][tokens[..., k]] for k in range(cfg.n_codebooks)
        )
        return x
    return params["embed"].astype(dt)[tokens]


def lm_logits(params, cfg: ModelConfig, x):
    dt = x.dtype
    if cfg.n_codebooks:
        head = (
            jnp.swapaxes(params["embed"], 1, 2)
            if cfg.tie_embeddings else params["head"]
        )                                           # (K, D, Vp)
        return jnp.einsum("bsd,kdv->bskv", x, head.astype(dt))
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    return x @ head.astype(dt)


def forward(
    params,
    cfg: ModelConfig,
    tokens=None,              # (B,S) i32, or (B,S,K) for audio
    embeds=None,              # (B,S,D) for vlm (frontend stub output)
    positions=None,           # (B,S) or (B,S,3); default arange
    cache: Optional[dict] = None,
    cache_len=None,
    mode: str = "train",
    block_tables=None,        # (B, max_blocks) i32: paged decode cache
):
    if embeds is not None:
        x = embeds.astype(jnp.dtype(cfg.dtype))
        B, S = x.shape[:2]
    else:
        x = embed_tokens(params, cfg, tokens)
        B, S = tokens.shape[:2]
    if positions is None:
        if cache_len is None:
            off = jnp.zeros((B, 1), jnp.int32)
        else:
            cl = jnp.asarray(cache_len, jnp.int32)
            off = (jnp.maximum(cl, 0)[:, None] if cl.ndim == 1
                   else jnp.broadcast_to(cl, (B, 1)))
        positions = jnp.arange(S, dtype=jnp.int32)[None] + off
        if cfg.mrope:
            positions = jnp.broadcast_to(positions[..., None], (B, S, 3))
    x, cache, aux = run_layers(params, cfg, x, positions, cache, cache_len,
                               mode, block_tables)
    x = rmsnorm(x, params["final_ln"], cfg.norm_eps)
    logits = lm_logits(params, cfg, x)
    return logits, cache, aux
