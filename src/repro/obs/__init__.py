"""repro.obs — zero-dependency tracing + metrics for the whole fabric.

The paper's §2.4 logging counters (``core.stats``) cover the GLB core;
this package is the layer above it, threaded through the serve engine,
continuous-batching scheduler, radix cache, and replica balancer:

  trace.py    — Chrome trace_event spans/instants/counters (Perfetto),
                request-lifecycle async spans keyed by request id,
                NullTracer disabled default (one attribute check).
  metrics.py  — counters / gauges / fixed-bucket histograms with
                snapshot()/merged() compatible with
                core.stats.merge_place_stats, Prometheus rendering.
"""
from .trace import (NULL_TRACER, NullTracer, Tracer, clock_sync, now_us,
                    validate_chrome_trace)
from .metrics import (DEFAULT_BYTE_BUCKETS, DEFAULT_MS_BUCKETS, Counter,
                      Gauge, Histogram, MetricsRegistry,
                      quantiles_from_values)

__all__ = [
    "NULL_TRACER",
    "NullTracer",
    "Tracer",
    "clock_sync",
    "now_us",
    "validate_chrome_trace",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "quantiles_from_values",
    "DEFAULT_MS_BUCKETS",
    "DEFAULT_BYTE_BUCKETS",
]
