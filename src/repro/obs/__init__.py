"""repro.obs — zero-dependency tracing + metrics for the whole fabric.

The paper's §2.4 logging counters (``core.stats``) cover the GLB core;
this package is the layer above it, threaded through the serve engine,
continuous-batching scheduler, radix cache, and replica balancer:

  trace.py    — Chrome trace_event spans/instants/counters (Perfetto),
                request-lifecycle async spans keyed by request id,
                NullTracer disabled default (one attribute check).
  metrics.py  — counters / gauges / fixed-bucket histograms with
                snapshot()/merged() compatible with
                core.stats.merge_place_stats, Prometheus rendering.
  flight.py   — FlightRecorder: bounded ring-buffer tracer whose
                dump() is always balanced (synthesized opens for
                evicted begins) — always-on tracing in fixed memory.
  analyze.py  — trace analytics: per-request time attribution,
                replica utilization, steal efficiency, p99 critical
                path; the ``python -m repro.obs.analyze`` CI gate.
  slo.py      — SLOMonitor: declared TTFT/TPOT/queue-wait targets,
                rolling windows, multi-window burn-rate alerts.
"""
from .trace import (NULL_TRACER, NullTracer, Tracer, atomic_write_json,
                    clock_sync, now_us, validate_chrome_trace)
from .metrics import (DEFAULT_BYTE_BUCKETS, DEFAULT_MS_BUCKETS, Counter,
                      Gauge, Histogram, MetricsRegistry,
                      quantiles_from_values)
from .flight import FlightRecorder
from .slo import SLOMonitor, SLOTarget, parse_slo_spec

# analyze is exported lazily (PEP 562): `python -m repro.obs.analyze`
# imports this package BEFORE running analyze as __main__, and an eager
# import here would put a second copy in sys.modules (RuntimeWarning).
_ANALYZE_EXPORTS = ("TraceAnalysis", "analyze_trace", "check_invariants",
                    "render_markdown", "render_summary")


def __getattr__(name):
    if name in _ANALYZE_EXPORTS:
        from . import analyze
        return getattr(analyze, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "NULL_TRACER",
    "NullTracer",
    "Tracer",
    "atomic_write_json",
    "clock_sync",
    "now_us",
    "validate_chrome_trace",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "quantiles_from_values",
    "DEFAULT_MS_BUCKETS",
    "DEFAULT_BYTE_BUCKETS",
    "FlightRecorder",
    "TraceAnalysis",
    "analyze_trace",
    "check_invariants",
    "render_markdown",
    "render_summary",
    "SLOMonitor",
    "SLOTarget",
    "parse_slo_spec",
]
