"""Trace analytics: the *consume* side of the observability layer.

The GLB paper's scaling argument is an accounting exercise — §2.4 logs
per-worker time processing vs distributing, steals sent/received, and
workload shipped, and the efficiency table is those numbers reduced.
This module reproduces that table from OUR artifacts: it loads a Chrome
trace (a file written by ``Tracer.write``/``FlightRecorder.write``, a
raw trace dict, or a live tracer via its ``dump()``) and answers the
paper's questions against the serving fabric:

* **per-request waterfalls** — every request's wall-clock is carved
  exhaustively into ``queued / prefill / decode / preempted / migrating
  / unattributed`` from its async lifecycle spans, stitched across pids
  when the request migrated (span ownership travels with the request,
  DESIGN.md §10). ``unattributed`` is the residual by construction, so
  the buckets always sum to the wall-clock exactly; the invariant
  checked here (and gated in CI) is that the residual stays ≤1%.
* **per-replica utilization** — busy/prefill/decode/migrate splits and
  idle fractions from the duration spans, the paper's "time computing
  vs distributing" per place.
* **steal efficiency** — decode-time moved per migration KiB and moves
  per steal round, from the fabric balancer's instants + the migrated
  requests' own post-migration decode time: the paper's efficiency
  metrics recomputed from the timeline rather than from counters.
* **critical path** — the p99-latency request's waterfall, the thing a
  future SLO-aware scheduler must shorten.

Everything is stdlib-only (CI's analyze gate runs before any heavyweight
import) and renders as markdown (``render_markdown``) or JSON via the
CLI::

    python -m repro.obs.analyze BENCH_serve_trace.json
    python -m repro.obs.analyze trace.json --json --max-unattributed 0.01

The CLI exits non-zero on validator errors or attribution-invariant
violations — it IS the CI gate, not just a report generator.
"""
from __future__ import annotations

import argparse
import json
import sys
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .trace import validate_chrome_trace

# Lifecycle phase -> attribution bucket. A "queued" segment that follows
# a preemption is re-bucketed to "preempted": the request already held a
# slot, so that wait is scheduler-induced, not arrival queueing. A
# "queued" segment that follows a crash re-admission (a ``readmitted``
# instant) is re-bucketed to "recovering" for the same reason — that
# wait is failure-induced, not arrival queueing (DESIGN.md §15).
PHASE_BUCKET = {
    "queued": "queued",
    "prefill": "prefill",
    "decode": "decode",
    "migrate": "migrating",
}
BUCKETS = ("queued", "prefill", "decode", "preempted", "migrating",
           "recovering", "unattributed")


@dataclass
class Segment:
    """One contiguous phase occupation of a request's timeline."""
    phase: str
    bucket: str
    pid: int
    t0: float
    t1: float

    @property
    def dur(self) -> float:
        return self.t1 - self.t0


@dataclass
class RequestBreakdown:
    """One request's exhaustive wall-clock waterfall: lifecycle buckets,
    the segment list behind them, and its preemption/migration/
    re-admission event counts."""

    rid: str
    t_begin: float
    t_end: float
    buckets: Dict[str, float]
    segments: List[Segment]
    replicas: List[int]
    preemptions: int = 0
    migrations: int = 0
    readmissions: int = 0           # crash re-admissions (replica died)
    migration_bytes: float = 0.0
    post_migration_decode_us: float = 0.0
    tokens: int = 0
    flushed: bool = False
    truncated: bool = False

    @property
    def wall_us(self) -> float:
        return self.t_end - self.t_begin

    @property
    def unattributed_us(self) -> float:
        return self.buckets.get("unattributed", 0.0)

    @property
    def unattributed_frac(self) -> float:
        w = self.wall_us
        return self.unattributed_us / w if w > 0 else 0.0


@dataclass
class ReplicaReport:
    """One replica's busy/prefill/decode/migrate/idle split over the
    trace window — the paper's time-computing-vs-distributing row."""

    pid: int
    name: str
    window_us: float
    busy_us: float
    prefill_us: float
    decode_us: float
    migrate_us: float
    steps: int

    @property
    def idle_us(self) -> float:
        return max(0.0, self.window_us - self.busy_us)

    @property
    def utilization(self) -> float:
        return self.busy_us / self.window_us if self.window_us > 0 else 0.0


@dataclass
class StealReport:
    """Fabric-level steal efficiency — the paper's table, from traces."""
    supersteps: int = 0
    steal_rounds: int = 0
    tier1_rounds: int = 0           # rounds containing a queue steal
    tier2_rounds: int = 0           # rounds containing a live migration
    tier1_moves: int = 0            # queued requests re-submitted
    tier2_moves: int = 0            # live KV migrations landed
    tier2_modes: Dict[str, int] = field(default_factory=dict)
    migration_bytes: float = 0.0
    moved_decode_us: float = 0.0    # decode time requests ran post-move
    terminated_at_superstep: Optional[int] = None
    replicas_dead: int = 0          # replica_dead instants (DESIGN.md §15)
    readmissions: int = 0           # request_readmitted instants
    wedged: bool = False            # fabric_wedged instant present

    @property
    def moves(self) -> int:
        return self.tier1_moves + self.tier2_moves

    @property
    def moves_per_steal_round(self) -> float:
        return self.moves / self.steal_rounds if self.steal_rounds else 0.0

    # Per-tier round math: each tier divided by the rounds in which THAT
    # tier fired. The old single ratio silently mixed a double-counted
    # balancer total into one denominator, over-crediting queue steals
    # whenever live migrations also ran.
    @property
    def tier1_moves_per_round(self) -> float:
        return self.tier1_moves / self.tier1_rounds if self.tier1_rounds \
            else 0.0

    @property
    def tier2_moves_per_round(self) -> float:
        return self.tier2_moves / self.tier2_rounds if self.tier2_rounds \
            else 0.0

    @property
    def moved_decode_us_per_kib(self) -> float:
        kib = self.migration_bytes / 1024.0
        return self.moved_decode_us / kib if kib > 0 else 0.0


@dataclass
class TenantPrediction:
    """Per-tenant decode-length prediction accuracy, from the
    ``cost_sample`` instants the engine emits at request finish."""
    tenant: str
    samples: int = 0
    mean_abs_err: float = 0.0        # tokens
    bias: float = 0.0                # mean (predicted - actual), tokens


@dataclass
class PredictionReport:
    """Prediction-error attribution for the cost model (DESIGN.md §16):
    how far the decode-length predictions were from reality, per tenant
    and over time. ``early``/``late`` split the samples chronologically
    in half — a converging online predictor shows late ≤ early."""
    samples: int = 0
    mean_abs_err: float = 0.0        # tokens, all samples
    bias: float = 0.0                # mean signed error, tokens
    early_abs_err: float = 0.0       # first half of the run
    late_abs_err: float = 0.0        # second half of the run
    tenants: List[TenantPrediction] = field(default_factory=list)

    @property
    def converging(self) -> bool:
        return self.samples < 2 or self.late_abs_err <= self.early_abs_err


@dataclass
class TraceAnalysis:
    """The full analysis of one trace: request waterfalls, replica
    utilization, steal efficiency, and (when the cost model ran)
    prediction-error attribution — everything the markdown/JSON
    renderers and the CI invariants read."""

    requests: List[RequestBreakdown]
    replicas: List[ReplicaReport]
    steal: StealReport
    validator_problems: List[str]
    window_us: float
    slo_burn_alerts: int = 0
    flight: Optional[dict] = None
    prediction: Optional[PredictionReport] = None

    def request(self, rid) -> Optional[RequestBreakdown]:
        want = rid if str(rid).startswith("req") else f"req{rid}"
        for r in self.requests:
            if r.rid == want:
                return r
        return None

    def p99_request(self) -> Optional[RequestBreakdown]:
        return self.quantile_request(0.99)

    def quantile_request(self, q: float) -> Optional[RequestBreakdown]:
        done = [r for r in self.requests if r.wall_us > 0]
        if not done:
            return None
        done.sort(key=lambda r: r.wall_us)
        return done[min(int(q * (len(done) - 1) + 0.999999),
                        len(done) - 1)]

    def bucket_totals(self) -> Dict[str, float]:
        out = {b: 0.0 for b in BUCKETS}
        for r in self.requests:
            for b, v in r.buckets.items():
                out[b] = out.get(b, 0.0) + v
        return out

    def to_dict(self) -> dict:
        d = asdict(self)
        for r, rd in zip(self.requests, d["requests"]):
            rd["wall_us"] = r.wall_us
            rd["unattributed_frac"] = r.unattributed_frac
        for r, rd in zip(self.replicas, d["replicas"]):
            rd["idle_us"] = r.idle_us
            rd["utilization"] = r.utilization
        d["steal"]["moves"] = self.steal.moves
        d["steal"]["moves_per_steal_round"] = \
            self.steal.moves_per_steal_round
        d["steal"]["tier1_moves_per_round"] = \
            self.steal.tier1_moves_per_round
        d["steal"]["tier2_moves_per_round"] = \
            self.steal.tier2_moves_per_round
        d["steal"]["moved_decode_us_per_kib"] = \
            self.steal.moved_decode_us_per_kib
        if self.prediction is not None:
            d["prediction"]["converging"] = self.prediction.converging
        d["bucket_totals"] = self.bucket_totals()
        return d


# --------------------------------------------------------------- loading
def _load(source: Any) -> dict:
    """Accept a file path, a trace dict, or a live tracer (anything with
    ``dump()``)."""
    if isinstance(source, str):
        with open(source) as f:
            return json.load(f)
    if isinstance(source, dict):
        return source
    if hasattr(source, "dump"):
        return source.dump()
    raise TypeError(f"cannot load a trace from {type(source).__name__}")


# ----------------------------------------------------------- request pass
def _parse_requests(events: Sequence[dict]
                    ) -> Tuple[List[RequestBreakdown], float]:
    """Reconstruct per-request waterfalls from the async lifecycle
    events. The tracer guarantees one open phase per request at a time
    and closes under the PREVIOUS owner's pid on migration, so a plain
    linear scan per id recovers the exact segment list; the residual
    (transition gaps, pre-first-phase time) lands in ``unattributed``."""
    reqs: Dict[str, RequestBreakdown] = {}
    open_phase: Dict[str, Tuple[str, float, int]] = {}
    after_preempt: Dict[str, bool] = {}
    after_readmit: Dict[str, bool] = {}
    first_migrate_in: Dict[str, float] = {}
    migration_bytes = 0.0

    def close(rid: str, ts: float) -> None:
        op = open_phase.pop(rid, None)
        if op is None:
            return
        phase, t0, pid = op
        bucket = PHASE_BUCKET.get(phase, "unattributed")
        # Recovery wins over preemption: a re-admitted request's wait is
        # failure-induced whatever else happened to it before the crash.
        if phase == "queued" and after_readmit.get(rid):
            bucket = "recovering"
            after_readmit[rid] = False
            after_preempt[rid] = False
        elif phase == "queued" and after_preempt.get(rid):
            bucket = "preempted"
            after_preempt[rid] = False
        r = reqs[rid]
        r.segments.append(Segment(phase, bucket, pid, t0, ts))
        if pid not in r.replicas:
            r.replicas.append(pid)
        if (bucket == "decode" and rid in first_migrate_in
                and ts > first_migrate_in[rid]):
            r.post_migration_decode_us += ts - max(t0,
                                                   first_migrate_in[rid])

    for ev in events:
        if ev.get("cat") != "request":
            continue
        rid = ev.get("id")
        if rid is None:
            continue
        ph, name, ts = ev.get("ph"), ev.get("name"), ev.get("ts", 0.0)
        pid = ev.get("pid", 0)
        args = ev.get("args") or {}
        if rid not in reqs:
            reqs[rid] = RequestBreakdown(
                rid=rid, t_begin=ts, t_end=ts,
                buckets={b: 0.0 for b in BUCKETS}, segments=[],
                replicas=[])
        r = reqs[rid]
        if args.get("synthesized") or name == "(truncated)":
            # Flight-ring truncation: this request's early history was
            # evicted; its buckets are lower bounds, not exhaustive.
            r.truncated = True
        if ph == "b":
            if name == "request":
                r.t_begin = ts
            else:
                close(rid, ts)      # defensive: tracer closes first
                open_phase[rid] = (name, ts, pid)
        elif ph == "e":
            if name == "request":
                close(rid, ts)
                r.t_end = ts
                r.flushed = bool(args.get("flushed"))
                r.tokens = int(args.get("tokens", r.tokens))
            else:
                close(rid, ts)
        elif ph == "n":
            if name == "preempted":
                r.preemptions += 1
                after_preempt[rid] = True
            elif name == "migrated_out":
                r.migrations += 1
                b = float(args.get("bytes", 0.0))
                r.migration_bytes += b
                migration_bytes += b
            elif name == "migrated_in":
                first_migrate_in.setdefault(rid, ts)
            elif name == "readmitted":
                r.readmissions += 1
                after_readmit[rid] = True

    for rid, r in reqs.items():
        close(rid, r.t_end)         # unterminated trace tail
        for seg in r.segments:
            r.buckets[seg.bucket] = r.buckets.get(seg.bucket, 0.0) \
                + seg.dur
        attributed = sum(seg.dur for seg in r.segments)
        r.buckets["unattributed"] = r.wall_us - attributed
    out = sorted(reqs.values(), key=lambda r: r.t_begin)
    return out, migration_bytes


# ---------------------------------------------------------- duration pass
def _parse_spans(events: Sequence[dict]) -> List[Tuple[str, int, int,
                                                       float, float]]:
    """Rebuild (name, pid, tid, t0, t1) duration spans from B/E pairs
    (LIFO per track, same discipline the validator checks)."""
    stacks: Dict[tuple, List[Tuple[str, float]]] = {}
    spans: List[Tuple[str, int, int, float, float]] = []
    for ev in events:
        ph = ev.get("ph")
        if ph == "B":
            key = (ev.get("pid"), ev.get("tid"))
            stacks.setdefault(key, []).append(
                (ev.get("name", "?"), ev.get("ts", 0.0)))
        elif ph == "E":
            key = (ev.get("pid"), ev.get("tid"))
            stack = stacks.get(key)
            if stack:
                name, t0 = stack.pop()
                spans.append((name, key[0], key[1], t0,
                              ev.get("ts", t0)))
    return spans


def _analyze_replicas(events: Sequence[dict],
                      spans: Sequence[Tuple[str, int, int, float, float]],
                      window: Tuple[float, float]
                      ) -> List[ReplicaReport]:
    names: Dict[int, str] = {}
    for ev in events:
        if ev.get("ph") == "M" and ev.get("name") == "process_name":
            names[ev.get("pid")] = (ev.get("args") or {}).get("name", "")
    per_pid: Dict[int, Dict[str, float]] = {}
    steps: Dict[int, int] = {}
    for name, pid, tid, t0, t1 in spans:
        d = per_pid.setdefault(pid, {})
        d[name] = d.get(name, 0.0) + (t1 - t0)
        if name == "engine_step":
            steps[pid] = steps.get(pid, 0) + 1
    out: List[ReplicaReport] = []
    window_us = max(0.0, window[1] - window[0])
    for pid in sorted(per_pid):
        d = per_pid[pid]
        if "engine_step" not in d:
            continue                # fabric/sim track, not a replica
        prefill = d.get("prefill", 0.0) + d.get("prefill_chunk", 0.0)
        migrate = d.get("migrate_out", 0.0) + d.get("migrate_in", 0.0)
        # migrate_out/in run outside engine_step (the balancer drives
        # them between steps), so busy is the sum; paged prefill runs on
        # side tids DURING the step, so decode is the step remainder.
        busy = d["engine_step"] + migrate
        out.append(ReplicaReport(
            pid=pid, name=names.get(pid, f"pid {pid}"),
            window_us=window_us, busy_us=busy,
            prefill_us=prefill,
            decode_us=max(0.0, d["engine_step"] - prefill),
            migrate_us=migrate, steps=steps.get(pid, 0)))
    return out


def _analyze_steal(events: Sequence[dict],
                   spans: Sequence[Tuple[str, int, int, float, float]],
                   requests: Sequence[RequestBreakdown],
                   migration_bytes: float) -> StealReport:
    rep = StealReport(migration_bytes=migration_bytes)
    supersteps = sorted((t0, t1) for name, pid, tid, t0, t1 in spans
                        if name == "superstep")
    rep.supersteps = len(supersteps)
    tier1_ts: List[float] = []
    tier2_ts: List[float] = []
    for ev in events:
        if ev.get("ph") != "i":
            continue
        name, args = ev.get("name"), ev.get("args") or {}
        if name == "steal_queued":
            rep.tier1_moves += int(args.get("n", 1))
            tier1_ts.append(ev.get("ts", 0.0))
        elif name == "steal_live":
            rep.tier2_moves += 1
            mode = args.get("mode", "?")
            rep.tier2_modes[mode] = rep.tier2_modes.get(mode, 0) + 1
            tier2_ts.append(ev.get("ts", 0.0))
        elif name == "terminated":
            rep.terminated_at_superstep = int(args.get("superstep", 0))
        elif name == "replica_dead":
            rep.replicas_dead += 1
        elif name == "request_readmitted":
            rep.readmissions += 1
        elif name == "fabric_wedged":
            rep.wedged = True

    def _rounds(ts_list: List[float]) -> int:
        if not supersteps:
            # Steals emitted outside any superstep span (manual
            # balance() calls) count one round each so efficiency is
            # never divided by 0.
            return len(ts_list)
        return sum(1 for t0, t1 in supersteps
                   if any(t0 <= ts <= t1 for ts in ts_list))

    rep.tier1_rounds = _rounds(tier1_ts)
    rep.tier2_rounds = _rounds(tier2_ts)
    rep.steal_rounds = _rounds(tier1_ts + tier2_ts)
    # Only genuinely MIGRATED requests (a migrated_out was traced) credit
    # the steal-efficiency numerator: decode run after a crash
    # re-admission is recovery, not stealing, and shipped zero bytes.
    rep.moved_decode_us = sum(r.post_migration_decode_us
                              for r in requests if r.migrations > 0)
    return rep


def _analyze_predictions(events: Sequence[dict]
                         ) -> Optional[PredictionReport]:
    """Fold every ``cost_sample`` instant (ts-ordered) into a
    :class:`PredictionReport`; None when the trace has none (cost model
    not attached — the report section simply doesn't render)."""
    samples: List[Tuple[float, str, float, float]] = []
    for ev in events:
        if ev.get("ph") != "i" or ev.get("name") != "cost_sample":
            continue
        args = ev.get("args") or {}
        samples.append((ev.get("ts", 0.0), str(args.get("tenant", "")),
                        float(args.get("predicted", 0.0)),
                        float(args.get("actual", 0.0))))
    if not samples:
        return None
    samples.sort(key=lambda s: s[0])
    errs = [p - a for _, _, p, a in samples]
    half = len(errs) // 2
    rep = PredictionReport(
        samples=len(errs),
        mean_abs_err=sum(abs(e) for e in errs) / len(errs),
        bias=sum(errs) / len(errs),
        early_abs_err=(sum(abs(e) for e in errs[:half]) / half
                       if half else 0.0),
        late_abs_err=(sum(abs(e) for e in errs[half:])
                      / max(len(errs) - half, 1)),
    )
    by_tenant: Dict[str, List[float]] = {}
    for (_, tenant, p, a) in samples:
        by_tenant.setdefault(tenant, []).append(p - a)
    for tenant in sorted(by_tenant):
        es = by_tenant[tenant]
        rep.tenants.append(TenantPrediction(
            tenant=tenant, samples=len(es),
            mean_abs_err=sum(abs(e) for e in es) / len(es),
            bias=sum(es) / len(es)))
    return rep


# ------------------------------------------------------------ entry point
def analyze_trace(source: Any) -> TraceAnalysis:
    trace = _load(source)
    problems = validate_chrome_trace(trace)
    events = trace.get("traceEvents") or []
    requests, migration_bytes = _parse_requests(events)
    spans = _parse_spans(events)
    ts_all = [ev.get("ts", 0.0) for ev in events if ev.get("ts", 0) > 0]
    window = (min(ts_all), max(ts_all)) if ts_all else (0.0, 0.0)
    replicas = _analyze_replicas(events, spans, window)
    steal = _analyze_steal(events, spans, requests, migration_bytes)
    burns = sum(1 for ev in events
                if ev.get("ph") == "i" and ev.get("name") == "slo_burn")
    flight = (trace.get("otherData") or {}).get("flight")
    return TraceAnalysis(
        requests=requests, replicas=replicas, steal=steal,
        validator_problems=problems,
        window_us=max(0.0, window[1] - window[0]),
        slo_burn_alerts=burns, flight=flight,
        prediction=_analyze_predictions(events))


def check_invariants(analysis: TraceAnalysis,
                     max_unattributed: float = 0.01,
                     abs_slack_us: float = 50.0) -> List[str]:
    """The attribution contract CI gates on: for EVERY fully-recorded
    request, bucket sums equal wall-clock (residual is the unattributed
    bucket by construction) and that residual is within
    ``max(max_unattributed · wall, abs_slack_us)``; a negative residual
    beyond slack means segments overlapped — a tracer bug. Truncated
    (flight-ring) requests are exempt: their history is a suffix."""
    violations = list(analysis.validator_problems)
    for r in analysis.requests:
        if r.truncated:
            continue
        slack = max(max_unattributed * r.wall_us, abs_slack_us)
        u = r.unattributed_us
        if u > slack:
            violations.append(
                f"{r.rid}: unattributed {u:.0f}us of {r.wall_us:.0f}us "
                f"wall ({100 * r.unattributed_frac:.2f}% > "
                f"{100 * max_unattributed:.0f}%)")
        elif u < -abs_slack_us:
            violations.append(
                f"{r.rid}: overlapping segments ({u:.0f}us residual)")
    return violations


# -------------------------------------------------------------- rendering
def _us(v: float) -> str:
    if v >= 1e6:
        return f"{v / 1e6:.2f}s"
    if v >= 1e3:
        return f"{v / 1e3:.2f}ms"
    return f"{v:.0f}us"


def _pct(v: float) -> str:
    return f"{100 * v:.1f}%"


def render_markdown(analysis: TraceAnalysis,
                    max_unattributed: float = 0.01) -> str:
    a = analysis
    lines = ["# Trace analysis", ""]
    if a.flight:
        lines.append(
            f"_flight ring: capacity={a.flight.get('capacity')} "
            f"dropped={a.flight.get('dropped')} "
            f"synthesized_opens={a.flight.get('synthesized_opens')}_")
        lines.append("")
    lines.append(f"- window: **{_us(a.window_us)}**  ·  requests: "
                 f"**{len(a.requests)}**  ·  replicas: "
                 f"**{len(a.replicas)}**")
    if a.validator_problems:
        lines.append(f"- **VALIDATOR: {len(a.validator_problems)} "
                     f"problem(s)** — e.g. {a.validator_problems[0]}")
    else:
        lines.append("- validator: clean")
    viol = [v for v in check_invariants(a, max_unattributed)
            if v not in a.validator_problems]
    if viol:
        lines.append(f"- **ATTRIBUTION: {len(viol)} violation(s)** — "
                     f"e.g. {viol[0]}")
    else:
        lines.append(f"- attribution: every request ≥"
                     f"{_pct(1 - max_unattributed)} accounted")
    if a.slo_burn_alerts:
        lines.append(f"- **SLO burn alerts: {a.slo_burn_alerts}**")
    lines.append("")

    lines += ["## Request time attribution", "",
              "| bucket | total | share |", "|---|---:|---:|"]
    totals = a.bucket_totals()
    wall = sum(r.wall_us for r in a.requests) or 1.0
    for b in BUCKETS:
        lines.append(f"| {b} | {_us(totals.get(b, 0.0))} | "
                     f"{_pct(totals.get(b, 0.0) / wall)} |")
    lines.append("")

    if a.replicas:
        lines += ["## Replica utilization", "",
                  "| replica | busy | util | prefill | decode | migrate"
                  " | idle | steps |", "|---|---:|---:|---:|---:|---:|"
                  "---:|---:|"]
        for r in a.replicas:
            lines.append(
                f"| {r.name} | {_us(r.busy_us)} | "
                f"{_pct(r.utilization)} | {_us(r.prefill_us)} | "
                f"{_us(r.decode_us)} | {_us(r.migrate_us)} | "
                f"{_us(r.idle_us)} | {r.steps} |")
        lines.append("")

    s = a.steal
    lines += ["## Steal efficiency", ""]
    lines.append(f"- supersteps: {s.supersteps} (steal rounds: "
                 f"{s.steal_rounds})" +
                 (f", terminated at superstep "
                  f"{s.terminated_at_superstep}"
                  if s.terminated_at_superstep is not None else ""))
    lines.append(f"- moves: {s.moves} ({s.tier1_moves} queued + "
                 f"{s.tier2_moves} live KV"
                 + (f" {s.tier2_modes}" if s.tier2_modes else "") + ")")
    lines.append(f"- moves per steal round: "
                 f"{s.moves_per_steal_round:.2f} "
                 f"(tier-1 {s.tier1_moves_per_round:.2f}/round over "
                 f"{s.tier1_rounds}, tier-2 "
                 f"{s.tier2_moves_per_round:.2f}/round over "
                 f"{s.tier2_rounds})")
    lines.append(f"- migration payload: {s.migration_bytes / 1024:.1f} "
                 f"KiB; decode time moved: {_us(s.moved_decode_us)} "
                 f"({s.moved_decode_us_per_kib:.1f} us/KiB)")
    if s.replicas_dead or s.readmissions or s.wedged:
        lines.append(
            f"- **failures**: {s.replicas_dead} replica(s) dead, "
            f"{s.readmissions} request(s) re-admitted"
            + (", **fabric wedged**" if s.wedged else ""))
    lines.append("")

    if a.prediction is not None:
        p = a.prediction
        trend = "converging" if p.converging else "**diverging**"
        lines += ["## Prediction error", ""]
        lines.append(
            f"- {p.samples} scored prediction(s): mean |err| "
            f"{p.mean_abs_err:.1f} tokens, bias {p.bias:+.1f} "
            f"(early {p.early_abs_err:.1f} → late {p.late_abs_err:.1f}: "
            f"{trend})")
        if p.tenants:
            lines += ["", "| tenant | samples | mean abs err | bias |",
                      "|---|---:|---:|---:|"]
            for t in p.tenants:
                lines.append(
                    f"| {t.tenant or '(default)'} | {t.samples} | "
                    f"{t.mean_abs_err:.1f} | {t.bias:+.1f} |")
        lines.append("")

    p99 = a.p99_request()
    if p99 is not None:
        lines += [f"## Critical path (p99 request: {p99.rid}, "
                  f"{_us(p99.wall_us)} wall)", ""]
        lines.append(f"- replicas {p99.replicas}, "
                     f"{p99.preemptions} preemption(s), "
                     f"{p99.migrations} migration(s), "
                     f"{p99.tokens} token(s)")
        lines += ["", "| phase | bucket | replica | start | dur |",
                  "|---|---|---:|---:|---:|"]
        for seg in p99.segments:
            lines.append(f"| {seg.phase} | {seg.bucket} | {seg.pid} | "
                         f"+{_us(seg.t0 - p99.t_begin)} | "
                         f"{_us(seg.dur)} |")
        if p99.unattributed_us > 0:
            lines.append(f"| _(unattributed)_ |  |  |  | "
                         f"{_us(p99.unattributed_us)} |")
        lines.append("")
    return "\n".join(lines)


def render_summary(analysis: TraceAnalysis) -> str:
    """Compact multi-line fabric report for example scripts' exits."""
    a = analysis
    totals = a.bucket_totals()
    wall = sum(r.wall_us for r in a.requests) or 1.0
    parts = [f"{b}={_pct(totals.get(b, 0.0) / wall)}"
             for b in BUCKETS if totals.get(b, 0.0) > 0]
    lines = [f"trace: {len(a.requests)} request(s) over "
             f"{_us(a.window_us)}; attribution " + " ".join(parts)]
    for r in a.replicas:
        lines.append(f"  {r.name}: util {_pct(r.utilization)} "
                     f"(prefill {_us(r.prefill_us)}, decode "
                     f"{_us(r.decode_us)}, migrate {_us(r.migrate_us)}, "
                     f"idle {_us(r.idle_us)}; {r.steps} steps)")
    s = a.steal
    if s.moves:
        lines.append(
            f"  steals: {s.moves} move(s) in {s.steal_rounds} round(s), "
            f"{s.migration_bytes / 1024:.1f} KiB shipped, "
            f"{s.moved_decode_us_per_kib:.1f} us decode/KiB")
    if s.replicas_dead or s.wedged:
        lines.append(
            f"  failures: {s.replicas_dead} replica(s) dead, "
            f"{s.readmissions} re-admission(s)"
            + (", fabric WEDGED" if s.wedged else ""))
    if a.prediction is not None:
        p = a.prediction
        lines.append(
            f"  predictions: {p.samples} scored, mean |err| "
            f"{p.mean_abs_err:.1f} tokens "
            f"(early {p.early_abs_err:.1f} → late {p.late_abs_err:.1f})")
    p99 = a.p99_request()
    if p99 is not None:
        lines.append(f"  p99 request {p99.rid}: {_us(p99.wall_us)} "
                     f"({len(p99.segments)} segments, "
                     f"{_pct(p99.unattributed_frac)} unattributed)")
    return "\n".join(lines)


def headline(analysis: TraceAnalysis) -> str:
    """One-liner for ``uts_demo --trace``-style post-run output."""
    a = analysis
    ok = not a.validator_problems and not check_invariants(a)
    util = (sum(r.utilization for r in a.replicas) / len(a.replicas)
            if a.replicas else 0.0)
    return (f"analysis: {'ok' if ok else 'VIOLATIONS'}; "
            f"{len(a.requests)} request(s), "
            f"{len(a.replicas)} replica(s) at {_pct(util)} mean util, "
            f"{a.steal.moves} steal move(s)")


# -------------------------------------------------------------------- CLI
def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.analyze",
        description="Analyze a Chrome trace produced by the serving "
                    "fabric: request attribution, replica utilization, "
                    "steal efficiency. Exits 1 on validator errors or "
                    "attribution-invariant violations (the CI gate).")
    ap.add_argument("trace", help="path to a trace JSON file")
    ap.add_argument("--json", action="store_true",
                    help="emit the analysis as JSON instead of markdown")
    ap.add_argument("--out", help="also write the report to this path")
    ap.add_argument("--summary",
                    help="append the markdown report to this file "
                         "(e.g. $GITHUB_STEP_SUMMARY)")
    ap.add_argument("--max-unattributed", type=float, default=0.01,
                    help="max unattributed fraction of any request's "
                         "wall-clock (default 0.01)")
    args = ap.parse_args(argv)

    analysis = analyze_trace(args.trace)
    if args.json:
        report = json.dumps(analysis.to_dict(), indent=2, default=float)
    else:
        report = render_markdown(analysis, args.max_unattributed)
    print(report)
    if args.out:
        with open(args.out, "w") as f:
            f.write(report if not args.json
                    else render_markdown(analysis,
                                         args.max_unattributed))
    if args.summary:
        with open(args.summary, "a") as f:
            f.write(render_markdown(analysis, args.max_unattributed)
                    + "\n")

    violations = check_invariants(analysis, args.max_unattributed)
    if violations:
        print(f"\nFAIL: {len(violations)} violation(s):",
              file=sys.stderr)
        for v in violations[:20]:
            print(f"  - {v}", file=sys.stderr)
        return 1
    print(f"\nOK: {len(analysis.requests)} request(s) fully attributed "
          f"(<= {100 * args.max_unattributed:.0f}% unattributed each)",
          file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
