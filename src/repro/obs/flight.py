"""Flight recorder: a bounded ring-buffer tracer that makes always-on
tracing a fixed-memory default instead of an unbounded opt-in.

``FlightRecorder`` IS a :class:`~repro.obs.trace.Tracer` — every emit
site (engine, scheduler, radix cache, balancer, SLO monitor) works
unchanged — but events land in a drop-oldest ring of ``capacity``
entries, so a fabric can run traced forever and still hold the last N
events when something goes wrong (the black-box-recorder pattern).

The hard part is the export contract: the oldest half of a span pair
falls off the ring first (its ``B``/``b`` is older than its ``E``/``e``
by construction), so a naive JSON dump of the ring is unbalanced and
Perfetto/``validate_chrome_trace`` reject it. :meth:`FlightRecorder.dump`
therefore synthesizes an open at the ring's start timestamp for every
close whose open was evicted — ``(truncated)`` duration spans per
``(pid, tid)`` track and ``(truncated)`` async opens per ``(cat, id)``
— and re-emits process/thread metadata (kept OUT of the ring so names
never age out). The result passes ``validate_chrome_trace`` at EVERY
capacity (tested from 1 upward), and ``otherData.flight`` records
``capacity`` / ``dropped`` / ``synthesized_opens`` so a reader knows
how much history was lost.

``dump()`` is non-destructive (the recorder keeps recording after an
export) and ``write()`` routes through the same atomic temp-file +
``os.replace`` path as ``Tracer.write``.

Overhead: an append to a maxlen deque is O(1) like the list append the
unbounded tracer does; ``bench_serve``'s ``serve_flight_overhead`` row
gates that a live flight recorder adds ZERO host syncs (hard) with the
ring bounded far below the event count of the run.
"""
from __future__ import annotations

from collections import deque
from typing import Dict, Tuple

from .trace import Tracer, atomic_write_json, now_us


class _Ring(deque):
    """Drop-oldest deque that counts how many events it evicted."""

    def __init__(self, maxlen: int):
        super().__init__(maxlen=maxlen)
        self.dropped = 0

    def append(self, ev) -> None:
        if len(self) == self.maxlen:
            self.dropped += 1
        super().append(ev)


class FlightRecorder(Tracer):
    """Bounded-memory tracer with the full ``Tracer`` API. ``capacity``
    is the maximum number of retained events; everything older is
    dropped (oldest first) and only counted."""

    def __init__(self, capacity: int = 4096, cat: str = "serve"):
        if capacity < 1:
            raise ValueError(f"flight ring capacity must be >= 1, "
                             f"got {capacity}")
        super().__init__(cat=cat)
        self.capacity = capacity
        self.events = _Ring(maxlen=capacity)
        # Names live OUTSIDE the ring: a metadata event that aged out
        # would leave pids anonymous in the dump, and metadata occupying
        # ring slots would shrink the useful history.
        self._pid_names: Dict[int, str] = {}
        self._tid_names: Dict[Tuple[int, int], str] = {}

    @property
    def dropped(self) -> int:
        return self.events.dropped

    # ------------------------------------------------------------- metadata
    def process_name(self, pid: int, name: str) -> None:
        self._pid_names[pid] = name

    def thread_name(self, pid: int, tid: int, name: str) -> None:
        self._tid_names[(pid, tid)] = name

    # --------------------------------------------------------------- export
    def dump(self) -> dict:
        """Balanced, validator-clean trace of the ring contents. Three
        synthesis passes over a COPY (recording continues untouched):

        1. close still-open spans/phases (the tracer's ``_stacks`` /
           ``_req_phase`` bookkeeping survives eviction, so this is
           exact — same as ``Tracer.dump``);
        2. for every close whose open fell off the ring, prepend a
           ``(truncated)`` open at the ring-start timestamp (the
           validator and Perfetto only need depth balance, so
           synthesized opens stack at the window edge);
        3. prepend fresh process/thread metadata from the name maps.
        """
        events = list(self.events)
        ts_close = now_us()
        for (pid, tid), stack in self._stacks.items():
            for _ in stack:
                events.append({"ph": "E", "ts": ts_close, "pid": pid,
                               "tid": tid})
        for rid, (phase, pid) in self._req_phase.items():
            if phase is not None:
                events.append({"name": phase, "cat": "request",
                               "ph": "e", "ts": ts_close, "pid": pid,
                               "tid": 0, "id": f"req{rid}"})
            events.append({"name": "request", "cat": "request",
                           "ph": "e", "ts": ts_close, "pid": pid,
                           "tid": 0, "id": f"req{rid}",
                           "args": {"flushed": True}})
        t0 = min((e["ts"] for e in events if e.get("ts", 0) > 0),
                 default=0.0)
        opens = []
        depth: Dict[tuple, int] = {}
        for ev in events:
            ph = ev.get("ph")
            if ph == "B":
                key = ("d", ev.get("pid"), ev.get("tid"))
                depth[key] = depth.get(key, 0) + 1
            elif ph == "E":
                key = ("d", ev.get("pid"), ev.get("tid"))
                if depth.get(key, 0) > 0:
                    depth[key] -= 1
                else:
                    opens.append({
                        "name": "(truncated)", "cat": "flight",
                        "ph": "B", "ts": t0, "pid": ev.get("pid"),
                        "tid": ev.get("tid"),
                        "args": {"synthesized": True},
                    })
            elif ph in ("b", "n", "e"):
                key = ("a", ev.get("cat"), ev.get("id"))
                if ph == "b":
                    depth[key] = depth.get(key, 0) + 1
                    continue
                if depth.get(key, 0) > 0:
                    if ph == "e":
                        depth[key] -= 1
                    continue
                # e with no open: open+close cancel (depth stays 0);
                # n with no open: the synthesized open covers the rest
                # of the window (a later e will consume it).
                opens.append({
                    "name": "(truncated)", "cat": ev.get("cat"),
                    "ph": "b", "ts": t0, "pid": ev.get("pid"),
                    "tid": 0, "id": ev.get("id"),
                    "args": {"synthesized": True},
                })
                if ph == "n":
                    depth[key] = depth.get(key, 0) + 1
        # The ring holds a contiguous SUFFIX of the event stream, so any
        # open in the ring whose close exists is ring-resident too, and
        # genuinely-open spans were closed above from _stacks/_req_phase.
        # Leftover positive depth therefore only comes from opens we
        # synthesized for orphan "n" instants — close them at the edge.
        for key, d in depth.items():
            for _ in range(d):
                if key[0] == "d":
                    events.append({"ph": "E", "ts": ts_close,
                                   "pid": key[1], "tid": key[2]})
                else:
                    events.append({"name": "(truncated)", "cat": key[1],
                                   "ph": "e", "ts": ts_close, "pid": 0,
                                   "tid": 0, "id": key[2],
                                   "args": {"synthesized": True}})
        meta = [{"name": "process_name", "ph": "M", "pid": pid, "tid": 0,
                 "ts": 0, "args": {"name": name}}
                for pid, name in sorted(self._pid_names.items())]
        meta += [{"name": "thread_name", "ph": "M", "pid": pid,
                  "tid": tid, "ts": 0, "args": {"name": name}}
                 for (pid, tid), name in sorted(self._tid_names.items())]
        return {
            "traceEvents": meta + opens + events,
            "displayTimeUnit": "ms",
            "otherData": {
                "clock_sync": self.sync,
                "flight": {
                    "capacity": self.capacity,
                    "dropped": self.events.dropped,
                    "synthesized_opens": len(opens),
                },
            },
        }

    def write(self, path: str) -> None:
        # No flush(): dump() balances the copy, the ring keeps recording.
        atomic_write_json(path, self.dump())
