"""Metrics registry: counters, gauges, and fixed-bucket histograms with
``snapshot()``/``merge()`` compatible with ``core.stats.merge_place_stats``
plus Prometheus text rendering for a future ingress.

This is the aggregate half of the observability layer (``obs.trace`` is
the timeline half): per-replica registries record request-latency
distributions (TTFT, time-per-output-token, queue wait, prefill chunk
ms, migration bytes/ms) and the fabric merges them the same way GLB
result collection merges place stats — ``snapshot()`` flattens every
instrument to plain numeric fields, so the existing
``merge_place_stats`` / ``fabric_summary`` machinery consumes registries
without knowing they exist.

Histograms are **fixed-bucket**: merging across replicas is exact
(bucket counts add), and quantiles are estimated by linear interpolation
inside the covering bucket — within one bucket width of the true sample
quantile by construction (asserted against numpy quantiles in
``tests/test_obs.py``). All instruments are plain-python and update in
O(1); nothing here touches the device.
"""
from __future__ import annotations

import bisect
import math
from typing import Dict, Iterable, List, Optional, Sequence

# Default latency buckets (ms): geometric-ish 0.05ms .. 30s. The serving
# engine's TTFT/queue-wait/chunk timings land here; fixed across the
# fabric so per-replica histograms merge bucket-for-bucket.
DEFAULT_MS_BUCKETS = (
    0.05, 0.1, 0.2, 0.5, 1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 200.0,
    500.0, 1000.0, 2000.0, 5000.0, 10000.0, 30000.0,
)
# Byte-size buckets (KiB-scale) for migration payloads.
DEFAULT_BYTE_BUCKETS = tuple(float(4 ** k * 256) for k in range(12))


class Counter:
    """Monotonic float counter."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Gauge:
    """Last-write-wins value (``set``) with a ``set_max`` helper for
    high-water marks."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def set_max(self, v: float) -> None:
        if v > self.value:
            self.value = float(v)


class Histogram:
    """Fixed-bucket histogram: ``bounds`` are the finite upper edges
    (ascending); one overflow bucket catches the rest. Tracks count,
    sum, min, max alongside the bucket counts, so snapshots expose both
    exact moments and estimated quantiles."""

    __slots__ = ("bounds", "counts", "count", "total", "vmin", "vmax")

    def __init__(self, bounds: Sequence[float] = DEFAULT_MS_BUCKETS):
        # User input is validated with real exceptions, not asserts —
        # asserts vanish under ``python -O`` and a silently-accepted bad
        # bucket layout corrupts every merge downstream.
        bounds = tuple(float(b) for b in bounds)
        if not bounds:
            raise ValueError("histogram needs at least one bucket bound")
        if not all(a < b for a, b in zip(bounds, bounds[1:])):
            raise ValueError(
                f"histogram bounds must be strictly ascending: {bounds}"
            )
        self.bounds = bounds
        self.counts = [0] * (len(bounds) + 1)
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        self.counts[bisect.bisect_left(self.bounds, v)] += 1
        self.count += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v

    def quantile(self, q: float) -> float:
        """Estimated ``q``-quantile (0..1): linear interpolation inside
        the covering bucket, clamped to the observed min/max so tiny
        samples do not report a bucket edge nobody hit."""
        if self.count == 0:
            return 0.0
        rank = q * (self.count - 1)
        cum = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if cum + c > rank:
                lo = self.bounds[i - 1] if i > 0 else 0.0
                hi = (self.bounds[i] if i < len(self.bounds)
                      else self.vmax)
                frac = (rank - cum + 1) / c     # position inside bucket
                est = lo + (hi - lo) * min(frac, 1.0)
                return min(max(est, self.vmin), self.vmax)
            cum += c
        return self.vmax

    def merge_from(self, other: "Histogram") -> None:
        if self.bounds != other.bounds:
            raise ValueError(
                "cannot merge histograms with different bucket layouts: "
                f"{self.bounds} vs {other.bounds}"
            )
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.count += other.count
        self.total += other.total
        self.vmin = min(self.vmin, other.vmin)
        self.vmax = max(self.vmax, other.vmax)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0


class MetricsRegistry:
    """Named instruments with lazy creation: ``counter(name)`` /
    ``gauge(name)`` / ``histogram(name, bounds)`` return the existing
    instrument or make one. A name belongs to exactly one kind."""

    def __init__(self):
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._hists: Dict[str, Histogram] = {}

    def _check_unique(self, name: str, kind: dict) -> None:
        for d in (self._counters, self._gauges, self._hists):
            if d is not kind and name in d:
                raise ValueError(f"metric {name!r} already registered "
                                 "as a different kind")

    def counter(self, name: str) -> Counter:
        if name not in self._counters:
            self._check_unique(name, self._counters)
            self._counters[name] = Counter()
        return self._counters[name]

    def gauge(self, name: str) -> Gauge:
        if name not in self._gauges:
            self._check_unique(name, self._gauges)
            self._gauges[name] = Gauge()
        return self._gauges[name]

    def histogram(self, name: str,
                  bounds: Sequence[float] = DEFAULT_MS_BUCKETS
                  ) -> Histogram:
        if name not in self._hists:
            self._check_unique(name, self._hists)
            self._hists[name] = Histogram(bounds)
        return self._hists[name]

    def reset(self) -> None:
        self._counters.clear()
        self._gauges.clear()
        self._hists.clear()

    # ------------------------------------------------------------ snapshot
    def snapshot(self) -> Dict[str, float]:
        """Flat numeric dict — the per-replica unit GLB result collection
        reduces (``merge_place_stats`` consumes these directly).
        Histograms flatten to ``_count/_sum/_mean/_p50/_p99/_max``."""
        out: Dict[str, float] = {}
        for name, c in self._counters.items():
            out[name] = c.value
        for name, g in self._gauges.items():
            out[name] = g.value
        for name, h in self._hists.items():
            out[f"{name}_count"] = float(h.count)
            out[f"{name}_sum"] = round(h.total, 6)
            out[f"{name}_mean"] = round(h.mean, 6)
            out[f"{name}_p50"] = round(h.quantile(0.50), 6)
            out[f"{name}_p99"] = round(h.quantile(0.99), 6)
            out[f"{name}_max"] = round(h.vmax, 6) if h.count else 0.0
        return out

    # --------------------------------------------------------------- merge
    @staticmethod
    def merged(regs: Iterable["MetricsRegistry"]) -> "MetricsRegistry":
        """Exact fabric-level merge: counters add, gauges take the max
        (every gauge in this stack is a high-water mark or a level whose
        fabric-wide worst case is the interesting number), histograms
        merge bucket counts — so quantiles of the MERGED distribution
        are available, not averages of per-replica quantiles."""
        out = MetricsRegistry()
        for reg in regs:
            for name, c in reg._counters.items():
                out.counter(name).inc(c.value)
            for name, g in reg._gauges.items():
                out.gauge(name).set_max(g.value)
            for name, h in reg._hists.items():
                out.histogram(name, h.bounds).merge_from(h)
        return out

    # ---------------------------------------------------------- prometheus
    def render_prometheus(self, prefix: str = "repro_") -> str:
        """Prometheus text exposition format (the contract a future
        ingress scrapes). Histograms use cumulative ``_bucket{le=}``
        series per the spec."""
        lines: List[str] = []
        for name in sorted(self._counters):
            full = prefix + name
            lines.append(f"# TYPE {full} counter")
            lines.append(f"{full} {_fmt(self._counters[name].value)}")
        for name in sorted(self._gauges):
            full = prefix + name
            lines.append(f"# TYPE {full} gauge")
            lines.append(f"{full} {_fmt(self._gauges[name].value)}")
        for name in sorted(self._hists):
            h = self._hists[name]
            full = prefix + name
            lines.append(f"# TYPE {full} histogram")
            cum = 0
            for bound, c in zip(h.bounds, h.counts):
                cum += c
                lines.append(f'{full}_bucket{{le="{_fmt(bound)}"}} {cum}')
            lines.append(f'{full}_bucket{{le="+Inf"}} {h.count}')
            lines.append(f"{full}_sum {_fmt(h.total)}")
            lines.append(f"{full}_count {h.count}")
        return "\n".join(lines) + "\n"


def _fmt(v: float) -> str:
    return str(int(v)) if float(v).is_integer() else repr(float(v))


def quantiles_from_values(values: Sequence[float], qs: Sequence[float],
                          bounds: Optional[Sequence[float]] = None
                          ) -> List[float]:
    """Convenience: run ``values`` through a fresh fixed-bucket histogram
    and read the requested quantiles — what a bench row does to report
    registry-derived percentiles next to numpy ones."""
    h = Histogram(bounds if bounds is not None else DEFAULT_MS_BUCKETS)
    for v in values:
        h.observe(v)
    return [h.quantile(q) for q in qs]
