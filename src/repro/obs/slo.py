"""SLO monitoring: declared latency targets, rolling attainment windows,
and multi-window burn-rate alerts.

The ROADMAP's SLO-aware admission/scheduling items need a measurement
substrate before any scheduler can optimize against it — this module is
that substrate. An :class:`SLOMonitor` holds declared targets (TTFT,
TPOT, queue wait — any ms-valued metric the engine observes), keeps a
rolling window of pass/fail samples per target, and evaluates
**multi-window burn rates** (the Google SRE alerting recipe): with an
objective of 99%, the error budget is 1%, and the *burn rate* is the
observed error rate divided by that budget. An alert fires only when
the burn exceeds the threshold in BOTH a long window (is the budget
really being consumed?) and a short window (is it still happening
NOW?) — fast detection without flapping on a single slow request.

Alert transitions are emitted as ``slo_burn`` / ``slo_burn_clear``
trace instants on the fabric track and counted in the registry, so the
analyzer (``obs.analyze``) and the fabric report both surface them.
Wiring: ``Engine(..., slo=monitor)`` feeds per-request observations at
the same sites that feed the metrics histograms;
``GLBReplicaBalancer(..., slo=monitor)`` binds the fabric tracer/pid,
calls :meth:`SLOMonitor.check` each balance pass, and appends
attainment lines to ``report()``.

Timestamps are explicit parameters (defaulting to the trace clock) so
tests drive the windows deterministically without monkeypatching.
Everything is plain python and O(window) worst case; the monitor is
optional everywhere and costs nothing when absent.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Deque, Dict, Iterable, List, Optional, Tuple

from .trace import NULL_TRACER, now_us

# (long_window_s, short_window_s, burn_rate_threshold): page-worthy fast
# burn and a slower ticket-worthy burn — the standard SRE pairing,
# scaled down to bench-run durations.
DEFAULT_WINDOWS = ((60.0, 5.0, 14.0), (300.0, 25.0, 6.0))


@dataclass(frozen=True)
class SLOTarget:
    """``metric`` must stay under ``threshold_ms`` for at least
    ``objective`` of requests (e.g. TTFT < 250 ms for 99%)."""
    metric: str
    threshold_ms: float
    objective: float = 0.99

    def __post_init__(self):
        if self.threshold_ms <= 0:
            raise ValueError(
                f"SLO threshold for {self.metric!r} must be positive, "
                f"got {self.threshold_ms}")
        if not 0.0 < self.objective < 1.0:
            raise ValueError(
                f"SLO objective for {self.metric!r} must be in (0, 1), "
                f"got {self.objective}")

    @property
    def error_budget(self) -> float:
        return 1.0 - self.objective


def parse_slo_spec(spec: str) -> List[SLOTarget]:
    """Parse a CLI spec like ``"ttft_ms=250,tpot_ms=50"`` (optionally
    ``ttft_ms=250@0.999`` to override the 99% objective)."""
    targets: List[SLOTarget] = []
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        if "=" not in part:
            raise ValueError(
                f"bad SLO spec {part!r}: expected metric=threshold_ms")
        metric, rhs = part.split("=", 1)
        objective = 0.99
        if "@" in rhs:
            rhs, obj = rhs.split("@", 1)
            objective = float(obj)
        targets.append(SLOTarget(metric.strip(), float(rhs),
                                 objective))
    return targets


class SLOMonitor:
    """Rolling SLO attainment + multi-window burn-rate alerting over
    declared targets. One monitor serves a whole fabric: every replica's
    engine/scheduler feeds ``observe()``, the balancer calls ``check()``
    once per superstep."""

    def __init__(self, targets: Iterable[SLOTarget],
                 windows: Tuple[Tuple[float, float, float], ...]
                 = DEFAULT_WINDOWS,
                 tracer=None, metrics=None, pid: int = 0):
        targets = list(targets)
        if not targets:
            raise ValueError("SLOMonitor needs at least one target")
        seen = set()
        for t in targets:
            if t.metric in seen:
                raise ValueError(f"duplicate SLO target {t.metric!r}")
            seen.add(t.metric)
        for long_s, short_s, burn in windows:
            if short_s >= long_s:
                raise ValueError(
                    f"short window {short_s}s must be < long window "
                    f"{long_s}s")
            if burn <= 1.0:
                raise ValueError(
                    f"burn threshold {burn} must be > 1 (1.0 = exactly "
                    "consuming the budget)")
        self.targets: Dict[str, SLOTarget] = {t.metric: t
                                              for t in targets}
        self.windows = tuple(windows)
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.metrics = metrics
        self.pid = pid
        horizon = max(w[0] for w in self.windows)
        self._horizon_us = horizon * 1e6
        # per metric: (ts_us, ok) samples within the longest window,
        # plus all-time totals for attainment reporting.
        self._samples: Dict[str, Deque[Tuple[float, bool]]] = {
            m: deque() for m in self.targets}
        self._total: Dict[str, int] = {m: 0 for m in self.targets}
        self._bad: Dict[str, int] = {m: 0 for m in self.targets}
        self._alerting: Dict[str, bool] = {m: False for m in self.targets}
        self.alerts_fired = 0

    def bind(self, tracer=None, metrics=None,
             pid: Optional[int] = None) -> None:
        """Late wiring: the balancer attaches its fabric tracer/pid to a
        monitor constructed before the fabric existed. Only unset
        fields are filled — explicit construction args win."""
        if tracer is not None and self.tracer is NULL_TRACER:
            self.tracer = tracer
        if metrics is not None and self.metrics is None:
            self.metrics = metrics
        if pid is not None and self.pid == 0:
            self.pid = pid

    def target_ms(self, metric: str) -> Optional[float]:
        """Declared threshold for ``metric`` in ms, or None when no
        target was declared — the scheduler's SLO-aware admission
        (DESIGN.md §16) reads its TTFT/queue-wait budgets through this
        instead of poking at ``targets`` directly."""
        t = self.targets.get(metric)
        return t.threshold_ms if t is not None else None

    # ------------------------------------------------------------- feeding
    def observe(self, metric: str, value_ms: float,
                ts_us: Optional[float] = None) -> None:
        """Record one request-level sample. Metrics without a declared
        target are ignored — call sites stay unconditional."""
        t = self.targets.get(metric)
        if t is None:
            return
        ts = now_us() if ts_us is None else ts_us
        ok = value_ms <= t.threshold_ms
        self._samples[metric].append((ts, ok))
        self._total[metric] += 1
        if not ok:
            self._bad[metric] += 1
        if self.metrics is not None:
            self.metrics.counter(f"slo_{metric}_total").inc()
            if not ok:
                self.metrics.counter(f"slo_{metric}_violations").inc()
        self._prune(metric, ts)

    def _prune(self, metric: str, now: float) -> None:
        q = self._samples[metric]
        cutoff = now - self._horizon_us
        while q and q[0][0] < cutoff:
            q.popleft()

    # ------------------------------------------------------------ alerting
    def _burn(self, metric: str, window_s: float, now: float) -> float:
        """Error rate inside the window divided by the error budget."""
        t = self.targets[metric]
        cutoff = now - window_s * 1e6
        total = bad = 0
        for ts, ok in reversed(self._samples[metric]):
            if ts < cutoff:
                break
            total += 1
            if not ok:
                bad += 1
        if total == 0:
            return 0.0
        return (bad / total) / t.error_budget

    def check(self, ts_us: Optional[float] = None) -> List[str]:
        """Evaluate every (target × window-pair); returns the metrics
        currently in alert. Fires ``slo_burn`` on entering the alert
        state and ``slo_burn_clear`` on leaving it (state transitions
        only — a sustained burn is ONE alert, not one per check)."""
        now = now_us() if ts_us is None else ts_us
        alerting: List[str] = []
        for metric in self.targets:
            self._prune(metric, now)
            hit = None
            for long_s, short_s, threshold in self.windows:
                burn_long = self._burn(metric, long_s, now)
                burn_short = self._burn(metric, short_s, now)
                if burn_long > threshold and burn_short > threshold:
                    hit = (long_s, short_s, threshold,
                           burn_long, burn_short)
                    break
            if hit is not None:
                alerting.append(metric)
            if hit is not None and not self._alerting[metric]:
                self._alerting[metric] = True
                self.alerts_fired += 1
                if self.metrics is not None:
                    self.metrics.counter("slo_burn_alerts").inc()
                if self.tracer.enabled:
                    long_s, short_s, threshold, bl, bs = hit
                    self.tracer.instant(
                        "slo_burn", pid=self.pid,
                        args={"metric": metric,
                              "threshold_ms":
                                  self.targets[metric].threshold_ms,
                              "window_s": long_s,
                              "burn_long": round(bl, 2),
                              "burn_short": round(bs, 2),
                              "burn_threshold": threshold})
            elif hit is None and self._alerting[metric]:
                self._alerting[metric] = False
                if self.tracer.enabled:
                    self.tracer.instant("slo_burn_clear", pid=self.pid,
                                        args={"metric": metric})
        return alerting

    # ----------------------------------------------------------- reporting
    def attainment(self) -> Dict[str, Dict[str, float]]:
        """All-time attainment per target (the fabric report's SLO
        block): observed fraction vs objective, sample counts, and
        whether the target was met."""
        out: Dict[str, Dict[str, float]] = {}
        for metric, t in self.targets.items():
            total, bad = self._total[metric], self._bad[metric]
            attained = (total - bad) / total if total else 1.0
            out[metric] = {
                "threshold_ms": t.threshold_ms,
                "objective": t.objective,
                "attained": attained,
                "total": float(total),
                "violations": float(bad),
                "met": float(attained >= t.objective),
            }
        return out

    def snapshot(self) -> Dict[str, float]:
        """Flat numeric dict for ``collect()``-style merging (same shape
        contract as registry snapshots)."""
        out: Dict[str, float] = {"slo_burn_alerts":
                                 float(self.alerts_fired)}
        for metric, a in self.attainment().items():
            out[f"slo_{metric}_attained"] = round(a["attained"], 6)
            out[f"slo_{metric}_met"] = a["met"]
            out[f"slo_{metric}_total"] = a["total"]
            out[f"slo_{metric}_violations"] = a["violations"]
        return out

    def report_lines(self) -> List[str]:
        lines = []
        for metric, a in self.attainment().items():
            status = "MET" if a["met"] else "MISSED"
            lines.append(
                f"slo {metric} < {a['threshold_ms']:g}ms: "
                f"{100 * a['attained']:.2f}% attained "
                f"(objective {100 * a['objective']:g}%, "
                f"{int(a['violations'])}/{int(a['total'])} over) "
                f"[{status}]")
        if self.alerts_fired:
            lines.append(f"slo burn alerts fired: {self.alerts_fired}")
        return lines
