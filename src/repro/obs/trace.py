"""Fabric-wide tracing: monotonic-clock spans + instant events exported
as Chrome ``trace_event`` JSON (load the file at https://ui.perfetto.dev).

The GLB paper makes per-worker *logging* a first-class library feature
(§2.4: time processing vs distributing, steals sent/received, workload
shipped); this module is the timeline-resolved generalization for the
whole stack — one trace vocabulary shared by taskbag GLB runs and the
LM serving fabric:

* **duration spans** (``ph: B/E``) — per-replica work: engine steps,
  prefill chunks, migration pack/land, GLB supersteps. Owned by the
  ``(pid, tid)`` track that opened them; ``Tracer`` keeps a per-track
  stack so ``end()`` needs no name and export can prove balance.
* **request lifecycle spans** (async ``ph: b/n/e``, keyed by request
  id) — ``queued -> prefill -> decode -> finished`` with ``preempted`` /
  ``resumed`` / ``migrated_out`` / ``migrated_in`` instants in between.
  Async events are keyed by ``id`` (not pid), so ONE shared Tracer
  stitches a request's life across every replica it visits: the replica
  that opens a phase is recorded in that event's ``pid``, and the next
  owner's ``req_phase`` closes it — span ownership transfers with the
  request (DESIGN.md §10).
* **counter tracks** (``ph: C``) — pool occupancy, queue depth, token
  budget split, fabric load vector: the measurement substrate for
  cost-modeled balancing.

Overhead contract: the default is the module-level :data:`NULL_TRACER`
whose ``enabled`` is False — every instrumentation site guards with
``if tracer.enabled:``, so the disabled hot path pays ONE attribute
check and no call, no allocation, no clock read. ``bench_serve``
measures tracer-on vs tracer-off tokens/s and CI warns past 5%.

Clock domain: timestamps are ``time.perf_counter_ns() / 1e3`` µs.
:func:`clock_sync` returns a ``(unix_ts, perf_us)`` pair; the tracer
stamps one into the export's ``otherData`` and ``benchmarks/run.py``
stamps one into every ``BENCH_*.json``, so bench rows and trace events
can be correlated on one axis.
"""
from __future__ import annotations

import json
import os
import tempfile
import time
from contextlib import contextmanager
from typing import Any, Dict, List, Optional


def now_us() -> float:
    """Monotonic microseconds — the Chrome trace_event clock."""
    return time.perf_counter_ns() / 1e3


def atomic_write_json(path: str, obj: Any) -> None:
    """Write ``obj`` as JSON via a temp file in the same directory plus
    ``os.replace`` — an interrupted run leaves either the previous
    complete file or the new complete file, never a truncated one
    (``BENCH_serve_trace.json`` is parsed by the CI analyze gate, so a
    half-written artifact would fail the wrong step). Used by
    ``Tracer.write`` and ``FlightRecorder.dump``."""
    d = os.path.dirname(os.path.abspath(path)) or "."
    fd, tmp = tempfile.mkstemp(dir=d, prefix=".trace.", suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(obj, f)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def clock_sync() -> Dict[str, float]:
    """One point relating the wall clock to the trace clock. Stamped
    into both trace exports and BENCH_*.json rows so the two artifacts
    share a time axis: ``unix = unix_ts + (ts - perf_us) / 1e6``."""
    return {"unix_ts": time.time(), "perf_us": now_us()}


class NullTracer:
    """The disabled tracer: every emit is a no-op and ``enabled`` is
    False, so guarded call sites (``if tracer.enabled:``) never even
    enter the method. Shared singleton: :data:`NULL_TRACER`."""

    enabled = False
    events: tuple = ()

    def begin(self, *a, **k):                   # pragma: no cover - no-op
        pass

    def end(self, *a, **k):                     # pragma: no cover - no-op
        pass

    @contextmanager
    def span(self, *a, **k):
        yield

    def instant(self, *a, **k):                 # pragma: no cover - no-op
        pass

    def counter(self, *a, **k):                 # pragma: no cover - no-op
        pass

    def req_begin(self, *a, **k):               # pragma: no cover - no-op
        pass

    def req_phase(self, *a, **k):               # pragma: no cover - no-op
        pass

    def req_instant(self, *a, **k):             # pragma: no cover - no-op
        pass

    def req_end(self, *a, **k):                 # pragma: no cover - no-op
        pass

    def process_name(self, *a, **k):            # pragma: no cover - no-op
        pass

    def thread_name(self, *a, **k):             # pragma: no cover - no-op
        pass

    def flush(self):                            # pragma: no cover - no-op
        pass

    def dump(self):                             # pragma: no cover - no-op
        return {"traceEvents": [], "displayTimeUnit": "ms",
                "otherData": {}}

    def write(self, path):                      # pragma: no cover - no-op
        pass


NULL_TRACER = NullTracer()


class Tracer:
    """Collects Chrome trace_event dicts in memory; ``write()`` emits a
    Perfetto-loadable JSON object. One Tracer is shared by every replica
    of a fabric (async request spans cross replicas); ``pid`` is the
    replica / place id, ``tid`` subdivides a replica's tracks."""

    enabled = True

    def __init__(self, cat: str = "serve"):
        self.events: List[dict] = []
        self.default_cat = cat
        self.sync = clock_sync()        # unix <-> perf_counter anchor
        self._stacks: Dict[tuple, List[str]] = {}   # (pid,tid) -> names
        self._req_phase: Dict[Any, tuple] = {}      # rid -> (phase, pid)
        self._named_pids: set = set()
        self._named_tids: set = set()

    # ------------------------------------------------------- duration spans
    def begin(self, name: str, pid: int = 0, tid: int = 0,
              cat: Optional[str] = None, args: Optional[dict] = None,
              ts: Optional[float] = None) -> None:
        ev = {"name": name, "cat": cat or self.default_cat, "ph": "B",
              "ts": now_us() if ts is None else ts, "pid": pid, "tid": tid}
        if args:
            ev["args"] = args
        self.events.append(ev)
        self._stacks.setdefault((pid, tid), []).append(name)

    def end(self, pid: int = 0, tid: int = 0,
            args: Optional[dict] = None, ts: Optional[float] = None) -> None:
        stack = self._stacks.get((pid, tid))
        if not stack:       # unmatched end: drop rather than corrupt
            return
        stack.pop()
        ev = {"ph": "E", "ts": now_us() if ts is None else ts,
              "pid": pid, "tid": tid}
        if args:
            ev["args"] = args
        self.events.append(ev)

    @contextmanager
    def span(self, name: str, pid: int = 0, tid: int = 0,
             cat: Optional[str] = None, args: Optional[dict] = None):
        self.begin(name, pid=pid, tid=tid, cat=cat, args=args)
        try:
            yield
        finally:
            self.end(pid=pid, tid=tid)

    # ------------------------------------------------------ instants/counters
    def instant(self, name: str, pid: int = 0, tid: int = 0,
                cat: Optional[str] = None,
                args: Optional[dict] = None) -> None:
        ev = {"name": name, "cat": cat or self.default_cat, "ph": "i",
              "ts": now_us(), "pid": pid, "tid": tid, "s": "t"}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def counter(self, name: str, values: Dict[str, float], pid: int = 0,
                tid: int = 0) -> None:
        self.events.append({
            "name": name, "cat": self.default_cat, "ph": "C",
            "ts": now_us(), "pid": pid, "tid": tid,
            "args": {k: float(v) for k, v in values.items()},
        })

    # --------------------------------------------- request lifecycle (async)
    # Async events share one timeline per (cat, id) regardless of which
    # pid emitted them — the mechanism that lets a request's spans stay
    # correctly parented when it migrates between replicas. The tracer
    # tracks the open phase per rid so phase transitions always close
    # the previous phase first (spans stay balanced by construction).
    def _aev(self, ph: str, name: str, rid, pid: int,
             args: Optional[dict] = None) -> None:
        ev = {"name": name, "cat": "request", "ph": ph, "ts": now_us(),
              "pid": pid, "tid": 0, "id": f"req{rid}"}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def req_begin(self, rid, pid: int = 0,
                  args: Optional[dict] = None) -> None:
        if rid in self._req_phase:
            return                       # already alive (e.g. resubmit)
        self._aev("b", "request", rid, pid, args)
        self._req_phase[rid] = (None, pid)

    def req_phase(self, rid, phase: str, pid: int = 0,
                  args: Optional[dict] = None) -> None:
        """Transition ``rid`` to ``phase``: closes the open phase (opened
        by whichever replica owned the request last) and opens the new
        one under ``pid``. Unknown rids are auto-begun, so a thief-side
        tracer that never saw submit() still emits balanced spans."""
        if rid not in self._req_phase:
            self.req_begin(rid, pid=pid)
        prev, prev_pid = self._req_phase[rid]
        if prev is not None:
            self._aev("e", prev, rid, prev_pid)
        self._aev("b", phase, rid, pid, args)
        self._req_phase[rid] = (phase, pid)

    def req_instant(self, rid, name: str, pid: int = 0,
                    args: Optional[dict] = None) -> None:
        if rid not in self._req_phase:
            self.req_begin(rid, pid=pid)
        self._aev("n", name, rid, pid, args)

    def req_end(self, rid, pid: int = 0,
                args: Optional[dict] = None) -> None:
        state = self._req_phase.pop(rid, None)
        if state is None:
            return
        phase, phase_pid = state
        if phase is not None:
            self._aev("e", phase, rid, phase_pid)
        self._aev("e", "request", rid, pid, args)

    # ------------------------------------------------------------- metadata
    def process_name(self, pid: int, name: str) -> None:
        if pid in self._named_pids:
            return
        self._named_pids.add(pid)
        self.events.append({"name": "process_name", "ph": "M", "pid": pid,
                            "tid": 0, "ts": 0, "args": {"name": name}})

    def thread_name(self, pid: int, tid: int, name: str) -> None:
        if (pid, tid) in self._named_tids:
            return
        self._named_tids.add((pid, tid))
        self.events.append({"name": "thread_name", "ph": "M", "pid": pid,
                            "tid": tid, "ts": 0, "args": {"name": name}})

    # -------------------------------------------------------------- export
    def flush(self) -> None:
        """Close every still-open duration span and request phase so the
        exported JSON is balanced even for an interrupted run."""
        for (pid, tid), stack in self._stacks.items():
            while stack:
                stack.pop()
                self.events.append({"ph": "E", "ts": now_us(),
                                    "pid": pid, "tid": tid})
        for rid in list(self._req_phase):
            self.req_end(rid, pid=self._req_phase[rid][1],
                         args={"flushed": True})

    def to_chrome(self) -> dict:
        """The Chrome trace_event JSON object format (call ``flush()``
        first — ``write()`` does — if balance matters)."""
        return {
            "traceEvents": self.events,
            "displayTimeUnit": "ms",
            "otherData": {"clock_sync": self.sync},
        }

    def dump(self) -> dict:
        """Balanced trace dict WITHOUT mutating tracer state: still-open
        duration spans and request phases are closed in the exported
        copy only, so a live tracer can be analyzed mid-run
        (``obs.analyze.analyze_trace`` calls this) and keep tracing."""
        events = list(self.events)
        ts = now_us()
        for (pid, tid), stack in self._stacks.items():
            for _ in stack:
                events.append({"ph": "E", "ts": ts, "pid": pid,
                               "tid": tid})
        for rid, (phase, pid) in self._req_phase.items():
            if phase is not None:
                events.append({"name": phase, "cat": "request",
                               "ph": "e", "ts": ts, "pid": pid,
                               "tid": 0, "id": f"req{rid}"})
            events.append({"name": "request", "cat": "request",
                           "ph": "e", "ts": ts, "pid": pid, "tid": 0,
                           "id": f"req{rid}",
                           "args": {"flushed": True}})
        return {
            "traceEvents": events,
            "displayTimeUnit": "ms",
            "otherData": {"clock_sync": self.sync},
        }

    def write(self, path: str) -> None:
        self.flush()
        atomic_write_json(path, self.to_chrome())


def validate_chrome_trace(trace: dict) -> List[str]:
    """Schema check used by tests and the bench artifact step: every
    event carries pid/tid/ts/ph, duration spans are balanced LIFO per
    (pid, tid), and async b/e are balanced per (cat, id). Returns a list
    of problems (empty = valid)."""
    problems: List[str] = []
    events = trace.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    stacks: Dict[tuple, int] = {}
    adepth: Dict[tuple, int] = {}
    for i, ev in enumerate(events):
        for field in ("ph", "ts", "pid", "tid"):
            if field not in ev:
                problems.append(f"event {i} missing {field!r}")
        ph = ev.get("ph")
        if ph in ("B", "M", "i", "C", "b", "n") and "name" not in ev:
            problems.append(f"event {i} (ph={ph}) missing name")
        if ph == "B":
            key = (ev.get("pid"), ev.get("tid"))
            stacks[key] = stacks.get(key, 0) + 1
        elif ph == "E":
            key = (ev.get("pid"), ev.get("tid"))
            depth = stacks.get(key, 0)
            if depth <= 0:
                problems.append(f"event {i}: E without open B on {key}")
            else:
                stacks[key] = depth - 1
        elif ph in ("b", "n", "e"):
            if "id" not in ev:
                problems.append(f"event {i} (ph={ph}) missing id")
            key = (ev.get("cat"), ev.get("id"))
            if ph == "b":
                adepth[key] = adepth.get(key, 0) + 1
            elif ph == "e":
                depth = adepth.get(key, 0)
                if depth <= 0:
                    problems.append(f"event {i}: async e without b {key}")
                else:
                    adepth[key] = depth - 1
            elif adepth.get(key, 0) <= 0:
                problems.append(f"event {i}: async n outside b..e {key}")
    for key, depth in stacks.items():
        if depth:
            problems.append(f"{depth} unclosed duration span(s) on {key}")
    for key, depth in adepth.items():
        if depth:
            problems.append(f"{depth} unclosed async span(s) on {key}")
    return problems
