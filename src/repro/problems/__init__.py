"""The paper's workloads, expressed against the GLB user contract (§2.3):

  fib.py   — the pedagogical appendix example (default ArrayList-style bag)
  uts.py   — Unbalanced Tree Search (§2.5): geometric tree over a splittable
             hash RNG; interval-splitting TaskBag; + pure-python oracle
  bc.py    — Betweenness Centrality (§2.6): exact Brandes on SSCA2 R-MAT
             graphs as frontier matvecs; resumable per-vertex state machine;
             + numpy oracle
  rmat.py  — SSCA2 R-MAT graph generator
"""
from . import fib, uts, bc, rmat  # noqa: F401
