"""Betweenness Centrality (paper §2.6) as a GLB problem.

Exact Brandes (K4approx = SCALE) on an SSCA2 R-MAT graph that is replicated
on every place — the paper's "very strong assumption" that the graph fits in
one place's memory, which makes tasks relocatable. A task item is a vertex
interval (low, high) (§2.6.2); split halves every interval; merge
concatenates; the result is the betweenness map, reduced element-wise.

The paper found that even a task granularity of ONE vertex was too coarse —
workers could not respond to steal requests mid-vertex — and rewrote the
per-vertex computation as an *interruptable state machine*. We implement
exactly that: the Brandes forward/backward sweeps live in `state` and
`process(budget)` advances a bounded number of frontier sweeps (each one
matvec against the replicated adjacency), yielding between sweeps. The
in-progress vertex is reported via ``work_in_state`` so GLB's hunger and
termination logic accounts for it.

Frontier sweeps are dense matvecs so the hot loop maps onto the MXU.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.problem import GLBProblem
from repro.core import taskbag as tb

ITEM_SPEC = {
    "lo": jax.ShapeDtypeStruct((), jnp.int32),
    "hi": jax.ShapeDtypeStruct((), jnp.int32),
}


def bc_problem(adj: np.ndarray, capacity: int = 512, static_init: bool = True):
    """adj: dense (N, N) float32 adjacency, row=src col=dst, replicated."""
    n = adj.shape[0]
    adj_const = np.asarray(adj, np.float32)

    def init_place(p, P):
        bag = tb.make_bag(ITEM_SPEC, capacity)
        if static_init:
            # Paper BC: vertices statically partitioned, GLB rebalances.
            lo = (p * n) // P
            hi = ((p + 1) * n) // P
            bag = tb.push_one(bag, {"lo": lo.astype(jnp.int32),
                                    "hi": hi.astype(jnp.int32)})
            bag["size"] = jnp.where(hi > lo, bag["size"], 0)
        else:
            bag = tb.push_one(
                bag, {"lo": jnp.int32(0), "hi": jnp.int32(n)}
            )
            bag["size"] = jnp.where(p == 0, bag["size"], 0)
        state = {
            "bc": jnp.zeros((n,), jnp.float32),
            "cur": jnp.int32(-1),    # in-progress source vertex
            "phase": jnp.int32(0),   # 0 = forward BFS, 1 = backward deps
            "level": jnp.int32(0),
            "dist": jnp.full((n,), -1, jnp.int32),
            "sigma": jnp.zeros((n,), jnp.float32),
            "delta": jnp.zeros((n,), jnp.float32),
        }
        return state, bag

    def process(state, bag, budget: int):
        A = jnp.asarray(adj_const)  # replicated reference state (§2.1)

        def start_vertex(st, b):
            b, item = tb.pop_tail(b)
            v = item["lo"]
            rest = {"lo": (item["lo"] + 1)[None], "hi": item["hi"][None]}
            b = tb.push_block(b, rest, (item["hi"] - item["lo"] > 1).astype(jnp.int32))
            st = dict(
                st,
                cur=v,
                phase=jnp.int32(0),
                level=jnp.int32(0),
                dist=jnp.full((n,), -1, jnp.int32).at[v].set(0),
                sigma=jnp.zeros((n,), jnp.float32).at[v].set(1.0),
                delta=jnp.zeros((n,), jnp.float32),
            )
            return st, b

        def forward_sweep(st):
            frontier = (st["dist"] == st["level"]).astype(jnp.float32)
            reach = (st["sigma"] * frontier) @ A        # contributions to dst
            new = (st["dist"] < 0) & (reach > 0)
            dist = jnp.where(new, st["level"] + 1, st["dist"])
            sigma = st["sigma"] + reach * new
            any_new = new.any()
            return dict(
                st,
                dist=dist,
                sigma=sigma,
                level=jnp.where(any_new, st["level"] + 1, st["level"]),
                phase=jnp.where(any_new, 0, 1).astype(jnp.int32),
            )

        def backward_sweep(st):
            # Predecessor accumulation from depth `level` to `level - 1`.
            at_l = (st["dist"] == st["level"]).astype(jnp.float32)
            coef = jnp.where(
                at_l > 0, (1.0 + st["delta"]) / jnp.maximum(st["sigma"], 1e-30), 0.0
            )
            contrib = A @ coef                          # sum over successors
            at_prev = (st["dist"] == st["level"] - 1).astype(jnp.float32)
            delta = st["delta"] + st["sigma"] * contrib * at_prev
            lvl = st["level"] - 1
            finished = lvl <= 0
            bc = jnp.where(
                finished,
                st["bc"] + delta.at[st["cur"]].set(0.0),  # exclude the source
                st["bc"],
            )
            return dict(
                st,
                delta=jnp.where(finished, jnp.zeros_like(delta), delta),
                level=jnp.where(finished, 0, lvl),
                bc=bc,
                cur=jnp.where(finished, -1, st["cur"]),
                phase=jnp.where(finished, 0, st["phase"]).astype(jnp.int32),
            )

        def cond(c):
            st, b, left = c
            has_work = (st["cur"] >= 0) | (b["size"] > 0)
            return (left > 0) & has_work

        def body(c):
            st, b, left = c
            need_start = st["cur"] < 0

            def do_start(args):
                st, b = args
                return start_vertex(st, b)

            st, b = jax.lax.cond(need_start, do_start, lambda a: a, (st, b))
            st = jax.lax.cond(
                st["phase"] == 0,
                forward_sweep,
                backward_sweep,
                st,
            )
            return st, b, left - 1

        state, bag, left = jax.lax.while_loop(
            cond, body, (state, bag, jnp.int32(budget))
        )
        return state, bag, jnp.int32(budget) - left

    def split(bag, k: int):
        blk = tb.read_front(bag, k)
        lane = jnp.arange(k, dtype=jnp.int32)
        in_bag = lane < jnp.minimum(bag["size"], k)
        c = blk["hi"] - blk["lo"]
        splittable = in_bag & (c >= 2)
        mid = blk["lo"] + (c + 1) // 2
        keep = dict(blk, hi=jnp.where(splittable, mid, blk["hi"]))
        bag2 = tb.write_front(bag, keep)
        give = {"lo": mid, "hi": blk["hi"]}
        items, count = tb.compact_block(give, splittable)
        return bag2, {"items": items, "count": count}

    def evacuate(state, bag):
        # Crash recovery (DESIGN.md §15): re-bag the in-progress source
        # vertex as a width-1 interval and reset the sweep. Exact,
        # because ``bc`` only accumulates when a backward sweep
        # FINISHES — a restarted vertex recomputes from scratch on the
        # survivor and contributes exactly once.
        v = jnp.maximum(state["cur"], 0)
        bag = tb.push_block(
            bag, {"lo": v[None], "hi": (v + 1)[None]},
            (state["cur"] >= 0).astype(jnp.int32),
        )
        state = dict(state, cur=jnp.int32(-1), phase=jnp.int32(0),
                     level=jnp.int32(0))
        return state, bag

    return GLBProblem(
        name=f"bc-n{n}",
        item_spec=ITEM_SPEC,
        capacity=capacity,
        init_place=init_place,
        process=process,
        split=split,
        merge=tb.merge_packet,
        result=lambda st: st["bc"],
        reduce_op="sum",
        work_in_state=lambda st: (st["cur"] >= 0).astype(jnp.int32),
        evacuate=evacuate,
    )
