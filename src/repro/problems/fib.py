"""Naive recursive Fibonacci as a GLB problem — the paper's appendix example.

A task is an integer i. Processing pops the newest task (the X10 code's
``removeLast``): i < 2 adds i to the local result; otherwise tasks i-1 and
i-2 are pushed. The bag is the paper's default ArrayList bag (split = half
off the end). The root task lives at place 0 (``init`` at the root place).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.problem import GLBProblem
from repro.core import taskbag as tb

ITEM_SPEC = {"n": jax.ShapeDtypeStruct((), jnp.int32)}


def fib_problem(n: int, capacity: int = 4096) -> GLBProblem:
    def init_place(p, P):
        bag = tb.make_bag(ITEM_SPEC, capacity)
        bag = tb.push_one(bag, {"n": jnp.int32(n)})
        bag["size"] = jnp.where(p == 0, bag["size"], 0)  # root task at place 0
        state = {"result": jnp.zeros((), jnp.int32)}
        return state, bag

    def process(state, bag, budget: int):
        def cond(c):
            _, b, left = c
            return (left > 0) & (b["size"] > 0) & (b["size"] + 2 <= capacity)

        def body(c):
            st, b, left = c
            b, item = tb.pop_tail(b)
            x = item["n"]
            leaf = x < 2
            st = {"result": st["result"] + jnp.where(leaf, x, 0)}
            block = {"n": jnp.stack([x - 1, x - 2])}
            b = tb.push_block(b, block, jnp.where(leaf, 0, 2).astype(jnp.int32))
            return st, b, left - 1

        state, bag, left = jax.lax.while_loop(
            cond, body, (state, bag, jnp.int32(budget))
        )
        return state, bag, jnp.int32(budget) - left

    def split(bag, k: int):
        return tb.split_tail_half(bag, k)

    return GLBProblem(
        name="fib",
        item_spec=ITEM_SPEC,
        capacity=capacity,
        init_place=init_place,
        process=process,
        split=split,
        merge=tb.merge_packet,
        result=lambda st: st["result"],
        reduce_op="sum",
    )


def fib_oracle(n: int) -> int:
    a, b = 0, 1
    for _ in range(n):
        a, b = b, a + b
    return a
