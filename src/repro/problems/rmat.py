"""SSCA2 R-MAT graph generator (paper §2.6.1 references SSCA2 v2.2).

Recursive-matrix sampling with the SSCA2 probabilities (a,b,c,d) =
(0.57, 0.19, 0.19, 0.05), N = 2^scale vertices, edgefactor*N directed edges
before dedup/self-loop removal. Deterministic in `seed`.
"""
from __future__ import annotations

import numpy as np


def rmat_graph(
    scale: int,
    edgefactor: int = 8,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
    seed: int = 7,
):
    """Returns (adj, n): dense float32 adjacency (row=src, col=dst), no
    self-loops, deduplicated. Dense is deliberate: the paper replicates the
    graph on every place ("small enough to fit in the memory of a single
    place") and the frontier sweeps become MXU-friendly matvecs."""
    n = 1 << scale
    m = edgefactor * n
    rng = np.random.default_rng(seed)
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    for bit in range(scale):
        u = rng.random(m)
        v = rng.random(m)
        # quadrant probabilities: a=TL, b=TR, c=BL, d=BR
        go_right = u >= a + c  # dst high bit
        go_down = np.where(go_right, v >= b / (b + (1 - a - b - c)), v >= a / (a + c))
        src |= go_down.astype(np.int64) << bit
        dst |= go_right.astype(np.int64) << bit
    keep = src != dst
    src, dst = src[keep], dst[keep]
    adj = np.zeros((n, n), dtype=np.float32)
    adj[src, dst] = 1.0
    return adj, n


def brandes_bc_oracle(adj: np.ndarray) -> np.ndarray:
    """Exact betweenness centrality, unweighted directed Brandes — the
    reference for the GLB BC problem. O(N*E) python/numpy; test-scale only."""
    n = adj.shape[0]
    neighbors = [np.nonzero(adj[v])[0] for v in range(n)]
    bc = np.zeros(n, dtype=np.float64)
    for s in range(n):
        dist = -np.ones(n, dtype=np.int64)
        sigma = np.zeros(n, dtype=np.float64)
        dist[s] = 0
        sigma[s] = 1.0
        order = [s]
        frontier = [s]
        level = 0
        while frontier:
            nxt = []
            for u in frontier:
                for v in neighbors[u]:
                    if dist[v] < 0:
                        dist[v] = level + 1
                        nxt.append(v)
                        order.append(v)
                    if dist[v] == level + 1:
                        sigma[v] += sigma[u]
            frontier = nxt
            level += 1
        delta = np.zeros(n, dtype=np.float64)
        for v in reversed(order):
            for w in neighbors[v]:
                if dist[w] == dist[v] + 1 and sigma[w] > 0:
                    delta[v] += sigma[v] / sigma[w] * (1.0 + delta[w])
        delta[s] = 0.0
        bc += delta
        bc[s] -= 0.0
    # remove the source's own contribution counted as t==v? Brandes' delta
    # already excludes v==s; pairwise BC(v) excludes v==t by construction.
    return bc
