"""Unbalanced Tree Search (paper §2.5) as a GLB problem.

Tree: fixed geometric law, branching factor b0 (paper: 4), splittable-hash
RNG seeded with r (paper: 19), depth cut-off d (paper: 13..20). A node's
child count is geometric with mean b0 (clamped at MAX_CHILD; the tail beyond
32 has probability 0.8^32 ≈ 8e-4, noted in DESIGN.md). All nodes are treated
equally irrespective of depth, as the paper requires.

Task representation is the paper's (§2.5.2): a task item is a tree node as a
triple ``(descriptor, low, high)`` — descriptor is the node's hash state and
[low, high) the interval of its unexplored children — plus the node depth.
Split halves every (well, the oldest K) node's interval: n(d,l,h) ->
keep n1(d,l,mid) / give n2(d,mid,h); nodes with a single child are not split
("cheaper to count locally than move it"). Merge concatenates.

The child-generation hash is a 32-bit finalizer (splitmix/murmur-style)
computed identically by the jnp implementation and the pure-python oracle
(`uts_oracle`); geometric sampling uses integer threshold compares so the two
are bit-exact.

This hot loop (hash a block of children + geometric counts) is the paper's
``process`` hot spot and is what ``repro.kernels.uts_expand`` implements as a
Pallas TPU kernel; here we use the jnp reference (kernels/ref.py shares it).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.problem import GLBProblem
from repro.core import taskbag as tb

MAX_CHILD = 32

ITEM_SPEC = {
    "d0": jax.ShapeDtypeStruct((), jnp.uint32),
    "d1": jax.ShapeDtypeStruct((), jnp.uint32),
    "depth": jax.ShapeDtypeStruct((), jnp.int32),
    "lo": jax.ShapeDtypeStruct((), jnp.int32),
    "hi": jax.ShapeDtypeStruct((), jnp.int32),
}

# ---------------------------------------------------------------- hashing
_C1, _C2, _C3, _C4 = 0x7FEB352D, 0x846CA68B, 0x9E3779B9, 0x85EBCA77


def fmix32(h, xp):
    """32-bit finalizer (splitmix/murmur-style avalanche)."""
    u = xp.uint32
    h = h ^ (h >> u(16))
    h = h * u(_C1)
    h = h ^ (h >> u(15))
    h = h * u(_C2)
    h = h ^ (h >> u(16))
    return h


def child_hash(d0, d1, i, xp):
    """Splittable RNG: descriptor of child `i` of node (d0, d1)."""
    u = xp.uint32
    i = xp.asarray(i).astype(xp.uint32) if xp is np else i.astype(xp.uint32)
    h0 = fmix32(d0 + i * u(_C3), xp)
    h1 = fmix32((d1 ^ h0) + i * u(_C4), xp)
    h0 = fmix32(h0 ^ h1, xp)
    return h0, h1


def geom_thresholds(b0: float) -> np.ndarray:
    """T_k = floor(((b0/(1+b0))^k) * 2^32): child count = #{k: u < T_k}.

    Integer compares keep jnp and numpy bit-identical; E[count] ~= b0."""
    p_cont = b0 / (1.0 + b0)
    t = np.floor((p_cont ** np.arange(1, MAX_CHILD + 1)) * 2.0**32)
    return np.minimum(t, 2.0**32 - 1).astype(np.uint32)


def child_count(h0, thresholds, xp):
    u = h0[..., None] if xp is jnp else np.asarray(h0)[..., None]
    return (u < thresholds).sum(axis=-1).astype(xp.int32)


def root_desc(seed: int, xp):
    u = xp.uint32
    d0 = fmix32(u(seed) * u(_C3) + u(0x12345678), xp)
    d1 = fmix32((u(seed) ^ u(0xDEADBEEF)) * u(_C4) + u(1), xp)
    return d0, d1


# ------------------------------------------------------------ GLB problem
def uts_problem(
    b0: float = 4.0,
    depth: int = 8,
    seed: int = 19,
    capacity: int = 8192,
    gen_width: int = 0,  # child-gen vector width; 0 => GLB n at call time
) -> GLBProblem:
    thresholds_np = geom_thresholds(b0)

    # UTS convention: the root has exactly round(b0) children (a geometric
    # draw would make ~1/(1+b0) of all trees trivially empty).
    root_children = max(1, int(round(b0)))

    def init_place(p, P):
        bag = tb.make_bag(ITEM_SPEC, capacity)
        d0, d1 = root_desc(seed, jnp)
        m_root = jnp.int32(root_children if depth > 0 else 0)
        root = {
            "d0": d0,
            "d1": d1,
            "depth": jnp.int32(0),
            "lo": jnp.int32(0),
            "hi": m_root,
        }
        bag = tb.push_one(bag, root)
        has_root = (p == 0) & (m_root > 0)
        bag["size"] = jnp.where(has_root, bag["size"], 0)
        # The root itself counts as one visited node (at place 0).
        state = {"count": jnp.where(p == 0, 1, 0).astype(jnp.int32)}
        return state, bag

    def process(state, bag, budget: int):
        width = gen_width or budget  # static block width for child hashing
        thr = jnp.asarray(thresholds_np)

        def cond(c):
            _, b, left = c
            room = b["size"] + width + 1 <= capacity
            return (left > 0) & (b["size"] > 0) & room

        def body(c):
            st, b, left = c
            b, node = tb.pop_tail(b)
            c_total = node["hi"] - node["lo"]
            g = jnp.minimum(c_total, left)

            j = jnp.arange(width, dtype=jnp.int32)
            mask = j < g
            idx = node["lo"] + j
            cd0, cd1 = child_hash(node["d0"], node["d1"], idx, jnp)
            child_depth = node["depth"] + 1
            m = jnp.where(
                (child_depth < depth) & mask, child_count(cd0, thr, jnp), 0
            )

            # Parent remainder goes back first; children land on top (DFS).
            rem = c_total - g
            parent = {
                "d0": node["d0"][None],
                "d1": node["d1"][None],
                "depth": node["depth"][None],
                "lo": (node["lo"] + g)[None],
                "hi": node["hi"][None],
            }
            b = tb.push_block(b, parent, (rem > 0).astype(jnp.int32))

            child_block = {
                "d0": cd0,
                "d1": cd1,
                "depth": jnp.full((width,), 0, jnp.int32) + child_depth,
                "lo": jnp.zeros((width,), jnp.int32),
                "hi": m,
            }
            child_block, n_child = tb.compact_block(child_block, mask & (m > 0))
            b = tb.push_block(b, child_block, n_child)

            st = {"count": st["count"] + g}
            return st, b, left - g

        state, bag, left = jax.lax.while_loop(
            cond, body, (state, bag, jnp.int32(budget))
        )
        return state, bag, jnp.int32(budget) - left

    def split(bag, k: int):
        blk = tb.read_front(bag, k)
        lane = jnp.arange(k, dtype=jnp.int32)
        in_bag = lane < jnp.minimum(bag["size"], k)
        c = blk["hi"] - blk["lo"]
        splittable = in_bag & (c >= 2)  # paper: single-child nodes not split
        mid = blk["lo"] + (c + 1) // 2  # keep ceil, give floor
        keep = dict(blk, hi=jnp.where(splittable, mid, blk["hi"]))
        bag2 = tb.write_front(bag, keep)
        give = {
            "d0": blk["d0"],
            "d1": blk["d1"],
            "depth": blk["depth"],
            "lo": mid,
            "hi": blk["hi"],
        }
        items, count = tb.compact_block(give, splittable)
        return bag2, {"items": items, "count": count}

    return GLBProblem(
        name=f"uts-b{b0}-d{depth}",
        item_spec=ITEM_SPEC,
        capacity=capacity,
        init_place=init_place,
        process=process,
        split=split,
        merge=tb.merge_packet,
        result=lambda st: st["count"],
        reduce_op="sum",
    )


# ------------------------------------------------------------------ oracle
def uts_oracle(b0: float = 4.0, depth: int = 8, seed: int = 19) -> int:
    """Sequential pure-python/numpy tree count, bit-identical hashing."""
    thr = geom_thresholds(b0)
    with np.errstate(over="ignore"):
        d0, d1 = root_desc(seed, np)
        count = 1
        if depth <= 0:
            return count
        m_root = max(1, int(round(b0)))  # fixed root branching, as above
        stack = [(np.uint32(d0), np.uint32(d1), 0, m_root)]
        while stack:
            p0, p1, dep, m = stack.pop()
            idx = np.arange(m, dtype=np.uint32)
            c0, c1 = child_hash(p0, p1, idx, np)
            count += m
            if dep + 1 < depth:
                mc = child_count(c0, thr, np)
                for i in range(m):
                    if mc[i] > 0:
                        stack.append(
                            (np.uint32(c0[i]), np.uint32(c1[i]), dep + 1, int(mc[i]))
                        )
        return count
