"""Serving substrate: continuous-batching engine (jitted fori_loop
multi-token decode steps, on-device sampling, split-KV flash-decode
attention) + GLB replica balancer."""
from .engine import Engine, GLBReplicaBalancer, Request  # noqa: F401
