"""Serving substrate: continuous-batching engine + GLB replica balancer."""
