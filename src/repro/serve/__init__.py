"""Serving substrate: continuous-batching engine (jitted fori_loop
multi-token decode steps, on-device sampling, split-KV/paged flash-decode
attention), paged KV-cache pool, radix prefix cache (shared-prefix KV
reuse + chunked prefill), admission/preemption scheduler, and the GLB
replica balancer."""
from .cost import (CostModel, CostParams,  # noqa: F401
                   DecodeLengthPredictor)
from .engine import Engine, GLBReplicaBalancer, Request  # noqa: F401
from .faults import Fault, FaultInjector  # noqa: F401
from .kvpool import KVPool, PoolExhausted, PoolStats  # noqa: F401
from .radix import RadixPrefixCache  # noqa: F401
from .scheduler import ContinuousBatchingScheduler, StepPlan  # noqa: F401
