"""Per-request cost model: predicted remaining block-seconds for
predictive, cost-weighted load balancing (DESIGN.md §16).

The fabric balancer through PR 8 is purely *reactive*: a replica steals
only once it is already starving, and it steals by queue depth — every
request counts as 1 regardless of how expensive it actually is. The
related work the ROADMAP points at names the two missing halves:
anticipate imbalance before it lands (arXiv 1909.07168) and treat
requests as indivisible real-valued loads diffused toward a balanced
state (arXiv 1308.0148). Both need the same primitive: a **cost
estimate per request**, so load can be balanced on predicted work
rather than on counts.

This module provides that primitive from three observable inputs:

* **prompt tokens** — known exactly at submit;
* **radix prefix-cache hit length** — tokens the engine will serve from
  cached KV blocks instead of recomputing (``RadixPrefixCache.
  hit_length``), known at estimate time per target replica;
* **predicted decode length** — drawn from a running per-tenant
  decode-length :class:`~repro.obs.metrics.Histogram` that updates
  online as requests finish. A tenant with too few samples falls back
  to the *global* histogram (all tenants pooled), and a cold fabric
  falls back to a configured prior — so the model always answers, and
  its answers sharpen as traffic flows.

The unit is **block-seconds**: KV pool blocks the request will occupy ×
the estimated seconds of accelerator work remaining (calibrated by
``us_per_prefill_token`` / ``us_per_decode_token``). The deliberate
simplification — occupancy is taken at the request's *final* footprint
rather than integrated over its growth — keeps the estimate monotone in
all three inputs and cheap enough to recompute every balance pass; the
balancer only ever compares costs, so a consistent over-approximation
cancels out.

Every prediction is stamped on the request (``req.predicted_decode``)
and scored when the request finishes: absolute error feeds an error
histogram, a ``cost_sample`` trace instant carries (predicted, actual,
tenant) for the analyzer's prediction-error attribution
(``obs.analyze``), and the finished length feeds the tenant histogram —
closing the online-learning loop. The reactive-parity contract
(DESIGN.md §16) is enforced upstream: a balancer *without* a cost model
takes code paths this module never touches.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.obs.metrics import Histogram

# Decode-length buckets in TOKENS (not ms): geometric 1..4096, tight at
# the short end where chat-style turns cluster. Fixed across tenants so
# per-tenant histograms merge exactly, same contract as the ms buckets.
DECODE_LEN_BUCKETS = (
    1.0, 2.0, 4.0, 8.0, 12.0, 16.0, 24.0, 32.0, 48.0, 64.0, 96.0,
    128.0, 192.0, 256.0, 384.0, 512.0, 768.0, 1024.0, 2048.0, 4096.0,
)
# Absolute prediction-error buckets (tokens).
ERROR_BUCKETS = (0.0, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0,
                 256.0, 512.0, 1024.0)


@dataclasses.dataclass(frozen=True)
class CostParams:
    """Calibration + policy knobs for the cost model.

    ``us_per_prefill_token`` / ``us_per_decode_token`` convert token
    counts into service time (decode is far more expensive per token
    than batched prefill); ``prior_decode_tokens`` is the cold-start
    decode-length guess used before ANY request has finished;
    ``quantile`` is the point estimate drawn from the length histogram
    (0.5 = median — robust to the long tail; raise it to plan
    pessimistically); ``min_samples`` is how many finishes a tenant
    needs before its own histogram outvotes the global one."""

    us_per_prefill_token: float = 50.0
    us_per_decode_token: float = 400.0
    prior_decode_tokens: float = 64.0
    quantile: float = 0.5
    min_samples: int = 3

    def __post_init__(self):
        if self.us_per_prefill_token <= 0 or self.us_per_decode_token <= 0:
            raise ValueError("per-token costs must be positive")
        if self.prior_decode_tokens <= 0:
            raise ValueError("prior_decode_tokens must be positive")
        if not 0.0 < self.quantile < 1.0:
            raise ValueError(f"quantile must be in (0,1): {self.quantile}")
        if self.min_samples < 1:
            raise ValueError("min_samples must be >= 1")


class DecodeLengthPredictor:
    """Running per-tenant decode-length distribution.

    One fixed-bucket :class:`Histogram` (token-valued) per tenant plus
    one global histogram pooling every tenant. ``predict(tenant)``
    returns the configured quantile of the best-informed distribution:
    the tenant's own once it has ``min_samples`` finished requests, the
    global one once the *fabric* has that many, and the configured
    prior before that — the cold-start path. ``observe`` is O(1); the
    predictor carries no per-request state."""

    def __init__(self, params: CostParams = CostParams()):
        self.params = params
        self._tenants: Dict[str, Histogram] = {}
        self._global = Histogram(DECODE_LEN_BUCKETS)

    def observe(self, tenant: str, decoded_tokens: int) -> None:
        """Fold one finished request's decode length into the tenant's
        and the global distribution (the online update)."""
        h = self._tenants.get(tenant)
        if h is None:
            h = self._tenants[tenant] = Histogram(DECODE_LEN_BUCKETS)
        h.observe(float(decoded_tokens))
        self._global.observe(float(decoded_tokens))

    def samples(self, tenant: str) -> int:
        """Finished-request count backing ``tenant``'s own histogram."""
        h = self._tenants.get(tenant)
        return h.count if h is not None else 0

    def predict(self, tenant: str) -> float:
        """Predicted decode length (tokens) for the next request from
        ``tenant``: tenant quantile → global quantile → prior."""
        p = self.params
        h = self._tenants.get(tenant)
        if h is not None and h.count >= p.min_samples:
            return h.quantile(p.quantile)
        if self._global.count >= p.min_samples:
            return self._global.quantile(p.quantile)
        return p.prior_decode_tokens

    def source(self, tenant: str) -> str:
        """Which distribution ``predict`` would answer from right now:
        ``"tenant"``, ``"global"``, or ``"prior"`` (cold start)."""
        p = self.params
        h = self._tenants.get(tenant)
        if h is not None and h.count >= p.min_samples:
            return "tenant"
        if self._global.count >= p.min_samples:
            return "global"
        return "prior"


class CostModel:
    """Request-cost estimator + online prediction-error tracker.

    ``estimate(...)`` prices a request's REMAINING work in
    block-seconds; ``observe_finish(req)`` closes the loop when the
    request completes — scoring the prediction stamped at submit and
    feeding the actual length back into the predictor. One model is
    shared fabric-wide (like the tracer and the SLO monitor): every
    replica's finishes sharpen every replica's predictions."""

    def __init__(self, params: CostParams = CostParams(),
                 predictor: Optional[DecodeLengthPredictor] = None):
        self.params = params
        self.predictor = (predictor if predictor is not None
                          else DecodeLengthPredictor(params))
        self.error_hist = Histogram(ERROR_BUCKETS)
        # Chronological |predicted - actual| per finished request: the
        # convergence trace ("does the error shrink over a run?") used
        # by tests, the bench row, and the analyzer cross-check.
        self.errors: List[float] = []
        self.predictions = 0

    # ------------------------------------------------------------ pricing
    def predict_decode(self, tenant: str, max_new: int,
                       generated: int = 0) -> float:
        """Predicted TOTAL decode length for one request, clipped to
        what is still possible: at least the tokens already generated
        (the request demonstrably reached that length) and at most its
        ``max_new`` budget."""
        raw = self.predictor.predict(tenant)
        return float(min(max(raw, float(generated)), float(max_new)))

    def service_us(self, prefill_tokens: int, decode_tokens: float) -> float:
        """Calibrated service time (µs) for a given amount of prefill
        and decode work."""
        p = self.params
        return (prefill_tokens * p.us_per_prefill_token
                + decode_tokens * p.us_per_decode_token)

    def prefill_ms(self, prefill_tokens: int) -> float:
        """Predicted prefill service time in ms (the SLO admission
        slack term: time-to-first-token ≈ queue wait + this)."""
        return prefill_tokens * self.params.us_per_prefill_token / 1e3

    def estimate(self, prompt_tokens: int, cached_tokens: int,
                 generated: int, tenant: str, max_new: int,
                 block_size: int) -> float:
        """Predicted remaining block-seconds for one request.

        ``prompt_tokens`` is the (bucket-truncated) prompt length,
        ``cached_tokens`` the radix prefix-cache hit length (tokens the
        target replica would serve from cached blocks — 0 when there is
        no cache), ``generated`` the tokens already produced (0 for a
        queued request; >0 prices only the remaining decode of a
        running one). Monotone: longer prompts, colder caches, and
        longer predicted decodes all cost more."""
        predicted = self.predict_decode(tenant, max_new, generated)
        prefill_left = (0 if generated
                        else max(prompt_tokens - cached_tokens, 0))
        decode_left = max(predicted - generated, 1.0)
        final_tokens = prompt_tokens + predicted
        blocks = max(-(-final_tokens // max(block_size, 1)), 1.0)
        secs = self.service_us(prefill_left, decode_left) / 1e6
        return blocks * secs

    # ----------------------------------------------------- online updates
    def stamp(self, req) -> float:
        """Stamp the at-submit decode-length prediction on ``req`` (once
        — re-submits after steals/migrations keep the original stamp,
        exactly like ``t_submit``). Returns the stamped prediction."""
        if getattr(req, "predicted_decode", -1.0) < 0:
            req.predicted_decode = self.predict_decode(
                req.tenant, req.max_new, len(req.out))
            self.predictions += 1
        return req.predicted_decode

    def observe_finish(self, req) -> Optional[float]:
        """Score and learn from one finished request: absolute
        prediction error (tokens) into the error histogram and trace,
        actual length into the tenant histogram. Returns the error, or
        None when the request was never stamped (model attached
        mid-run)."""
        actual = len(req.out)
        err = None
        if getattr(req, "predicted_decode", -1.0) >= 0:
            err = abs(req.predicted_decode - actual)
            self.error_hist.observe(err)
            self.errors.append(err)
        self.predictor.observe(req.tenant, actual)
        return err

    # ------------------------------------------------------------- stats
    def mean_abs_error(self) -> float:
        """All-time mean |predicted - actual| in tokens."""
        return self.error_hist.mean

    def snapshot(self) -> Dict[str, float]:
        """Flat numeric view for ``collect()``-style merging."""
        half = len(self.errors) // 2
        early = (sum(self.errors[:half]) / half) if half else 0.0
        late = (sum(self.errors[half:]) / max(len(self.errors) - half, 1)
                if self.errors else 0.0)
        return {
            "cost_predictions": float(self.predictions),
            "cost_samples": float(len(self.errors)),
            "cost_mean_abs_err_tokens": round(self.mean_abs_error(), 3),
            "cost_early_abs_err_tokens": round(early, 3),
            "cost_late_abs_err_tokens": round(late, 3),
        }
