"""Serving engine: continuous batching over decode slots + GLB request
balancing across replicas.

Each replica owns a fixed pool of decode slots (static shapes). New
requests prefill into a free slot (prompts padded to a bucket length,
KV/conv state written into a reused preallocated row cache — no
``make_cache`` allocation churn per admission); all active slots advance
``steps_per_sync`` tokens per engine step inside ONE jitted
``lax.fori_loop`` decode: sampling (greedy or temperature, device-side
PRNG key threading) happens on device, per-slot done masks gate cache
writes and length/budget accounting, and each step emits an
(N, slots) token buffer the host drains with a single device->host sync —
~N× fewer host round-trips than the per-token loop (kept as
``step_legacy`` for benchmarking). Per-slot cache lengths (-1 marks an
idle slot: its cache/state is untouched) flow through to the split-KV
flash-decode kernel.

``paged=True`` swaps the fixed contiguous per-slot KV rows for the paged
subsystem (DESIGN.md §7): attention caches become flat block pools
(``models.make_paged_cache``) mapped per sequence by ``serve.kvpool``,
every engine step is planned by the continuous-batching scheduler
(``serve.scheduler`` — lookahead block reservation, watermark-based
preempt-and-requeue of the youngest sequence, per-step token budget,
strict-FIFO admission), and decode attention walks the block table
through the paged flash-decode kernel (`ops.attention(...,
block_tables=)`). A preempted request resumes by recomputing its cache
from prompt + generated-so-far, so greedy outputs are token-identical to
an uninterrupted run.

``prefix_cache=True`` adds the radix prefix cache (DESIGN.md §8): a
finished sequence's full KV blocks stay in a radix tree over its tokens,
a new admission forks the longest cached prefix (zero recompute, COW on
the partial tail) and prefills only its suffix, and cached blocks are
evicted LRU on pool pressure. ``prefill_chunk=N`` splits long prefills
into N-token chunks charged against the step token budget and
interleaved with decode (the ``paged_prefill`` kernel attends chunk
[s, e) to pool window [0, e)), so a long admission no longer stalls
co-scheduled decodes for one giant forward. Both features are
attention-family only (recurrent conv/ssm state cannot be forked).

The multi-replica balancer treats per-replica queue depth as the GLB size
vector and moves queued requests from overloaded to hungry replicas with
the same deterministic matching the task scheduler uses — the paper's
library applied to serving (DESIGN.md §4/§6). Hungry means "has a free
slot and free KV blocks", so replicas steal on memory headroom, not only
when fully idle.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import GLBParams, lifeline_buddies, match_steals
from repro.core.autotune import paged_block_kv
from repro.models import (decode_step, forward, make_cache,
                          make_paged_cache, sample_tokens)
from repro.models.config import ModelConfig

from .kvpool import KVPool
from .radix import RadixPrefixCache
from .scheduler import ContinuousBatchingScheduler


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


def _scrub_row(row):
    # The reused row cache carries the previous request's state.
    # Attention k/v tails are harmless (masked by cache length), but
    # recurrent conv/ssm state feeds prefill directly and must be zero.
    return {
        name: (leaf if name in ("k", "v") else jnp.zeros_like(leaf))
        for name, leaf in row.items()
    }


def _make_decode_loop(cfg: ModelConfig, max_seq: int, steps_per_sync: int,
                      temperature: float):
    """The jitted fori_loop fast path, shared by the contiguous and paged
    engines (``bt`` is the block table for paged caches, None for
    contiguous — one recurrence, so the done-mask/budget rules can never
    diverge between the two)."""
    vocab = cfg.vocab

    @jax.jit
    def decode_tokens(params, tokens, cache, bt, lens, budget, key):
        """steps_per_sync decode steps entirely on device. Carries per-slot
        done masks (idle: lens < 0; finished: budget == 0) and fills an
        (N, slots) token buffer (-1 where a slot emitted nothing) that the
        host drains with one sync."""
        B = tokens.shape[0]
        buf = jnp.full((steps_per_sync, B), -1, jnp.int32)

        def body(t, carry):
            tokens, cache, lens, budget, key, buf = carry
            active = (lens >= 0) & (budget > 0)
            step_lens = jnp.where(active, lens, -1)
            logits, cache = decode_step(params, cfg, tokens, cache,
                                        step_lens, block_tables=bt)
            key, sub = jax.random.split(key)
            nxt = sample_tokens(logits[:, 0, ..., :vocab], sub, temperature)
            nxt = jnp.where(active, nxt, -1)
            buf = buf.at[t].set(nxt)
            lens = jnp.where(active, lens + 1, lens)
            budget = jnp.where(active, budget - 1, budget)
            budget = jnp.where(lens >= max_seq - 1, 0, budget)  # cache full
            tokens = jnp.where(active[:, None], nxt[:, None], tokens)
            return tokens, cache, lens, budget, key, buf

        carry = (tokens, cache, lens, budget, key, buf)
        tokens, cache, lens, budget, key, buf = jax.lax.fori_loop(
            0, steps_per_sync, body, carry
        )
        return buf, cache, key

    return decode_tokens


def _make_fns(cfg: ModelConfig, temperature: float):
    vocab = cfg.vocab

    @jax.jit
    def prefill_into_slot(params, tokens, cache, slot, row, true_len, key):
        logits, row, _ = forward(
            params, cfg, tokens=tokens, cache=_scrub_row(row),
            cache_len=jnp.int32(0), mode="prefill",
        )
        def put(c, r):
            start = (0, slot) + (0,) * (c.ndim - 2)
            return jax.lax.dynamic_update_slice(c, r.astype(c.dtype), start)
        cache = jax.tree.map(put, cache, row)
        first = sample_tokens(
            logits[0, true_len - 1, ..., :vocab], key, temperature
        )
        return first, cache, row

    @jax.jit
    def decode_one(params, tokens, cache, lens):
        # Pre-fast-path decode: one step, greedy, logits -> host argmax is
        # the caller's job historically; argmax stays on device here but
        # the loop still syncs every token (step_legacy baseline).
        logits, cache = decode_step(params, cfg, tokens, cache, lens)
        nxt = jnp.argmax(logits[:, 0, ..., :vocab], axis=-1)
        return nxt.astype(jnp.int32), cache

    return prefill_into_slot, decode_one


def _make_paged_fns(cfg: ModelConfig, max_seq: int, block_size: int,
                    temperature: float):
    vocab = cfg.vocab
    max_blocks = max_seq // block_size

    @jax.jit
    def prefill_paged(params, tokens, cache, bt_scatter, slot, row,
                      true_len, key):
        """Prefill into the reused row cache, then scatter the row's KV
        blocks into the pool through ``bt_scatter`` ((max_blocks,) i32,
        out-of-bounds sentinel past the prompt's blocks => dropped).
        Recurrent conv/ssm leaves stay slot-dense and write at ``slot``.
        Retraces once per prompt bucket length (tokens.shape[1])."""
        logits, row, _ = forward(
            params, cfg, tokens=tokens, cache=_scrub_row(row),
            cache_len=jnp.int32(0), mode="prefill",
        )

        def put(name, c, r):
            if name in ("k", "v"):
                na = c.shape[0]
                rb = r[:, 0].reshape(
                    na, max_blocks, block_size, c.shape[-2], c.shape[-1]
                )
                return c.at[:, bt_scatter].set(rb.astype(c.dtype),
                                               mode="drop")
            start = (0, slot) + (0,) * (c.ndim - 2)
            return jax.lax.dynamic_update_slice(c, r.astype(c.dtype), start)

        cache = {name: put(name, cache[name], row[name]) for name in cache}
        first = sample_tokens(
            logits[0, true_len - 1, ..., :vocab], key, temperature
        )
        return first, cache, row

    @jax.jit
    def copy_block(cache, src, dst):
        """Apply one COW copy: physical block dst := src in the k/v
        pools (recurrent slot state is never shared, nothing to copy)."""
        out = dict(cache)
        for name in ("k", "v"):
            if name in cache:
                out[name] = cache[name].at[:, dst].set(cache[name][:, src])
        return out

    return prefill_paged, copy_block


def _make_chunk_fn(cfg: ModelConfig, temperature: float):
    """Chunked-prefill forward: tokens [start, start+C) of one sequence,
    writing k/v straight into the pool blocks through the block table and
    attending to the paged window [0, start+C) (paged_prefill kernel /
    oracle). Chunk shapes are exact (no bucket padding), so this retraces
    once per distinct chunk length. Returns the sampled token from the
    chunk's last position — callers use it only on the final chunk."""
    vocab = cfg.vocab

    @jax.jit
    def prefill_chunk(params, tokens, cache, bt, start, key):
        logits, cache, _ = forward(
            params, cfg, tokens=tokens, cache=cache, cache_len=start,
            mode="prefill", block_tables=bt[None, :],
        )
        last = sample_tokens(logits[0, -1, ..., :vocab], key, temperature)
        return last, cache

    return prefill_chunk


class Engine:
    def __init__(self, cfg: ModelConfig, params, max_slots: int = 4,
                 max_seq: int = 256, pad_len: int = 32,
                 steps_per_sync: int = 8, temperature: float = 0.0,
                 seed: int = 0, paged: bool = False,
                 block_size: Optional[int] = None,
                 num_blocks: Optional[int] = None,
                 watermark_blocks: int = 0,
                 token_budget: Optional[int] = None,
                 prefix_cache: bool = False,
                 prefill_chunk: Optional[int] = None):
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.max_seq = max_seq
        self.pad_len = pad_len
        self.steps_per_sync = steps_per_sync
        self.paged = paged
        self.prefix_cache = None       # set below for paged engines
        self.queue: deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * max_slots
        self.lens = np.full(max_slots, -1, np.int32)    # -1 => idle slot
        self.budget = np.zeros(max_slots, np.int32)     # tokens still owed
        self._row = make_cache(cfg, 1, max_seq, dtype=jnp.float32)
        self.tokens = np.zeros((max_slots, 1), np.int32)
        self._key = jax.random.key(seed)
        self.steps = 0
        self.tokens_out = 0
        self.host_syncs = 0    # blocking device->host transfer points
        self.peak_running = 0  # max concurrent sequences observed
        self.peak_occupancy = 0.0   # paged: max pool occupancy observed
        self.peak_fragmentation = 0.0
        if paged:
            bs = block_size or paged_block_kv(max_seq, cfg.hd)
            assert max_seq % bs == 0, (max_seq, bs)
            self.block_size = bs
            self.max_blocks = max_seq // bs
            self.num_blocks = num_blocks or max_slots * self.max_blocks
            assert self.num_blocks >= self.max_blocks, \
                "pool must fit at least one full-length sequence"
            self.pool = KVPool(self.num_blocks, bs)
            if prefix_cache or prefill_chunk is not None:
                # Recurrent conv/ssm state is not block-addressable: a
                # cached prefix (or an earlier chunk) carries hidden
                # state the pool cannot fork, so prefix reuse and
                # chunked prefill are attention-family features.
                assert cfg.family not in ("ssm", "hybrid"), (
                    "prefix cache / chunked prefill need stateless "
                    f"attention KV, not family={cfg.family!r}"
                )
            if prefix_cache:
                self.prefix_cache = RadixPrefixCache(self.pool)
            self.sched = ContinuousBatchingScheduler(
                self.pool, max_slots, lookahead=steps_per_sync,
                max_seq=max_seq, watermark_blocks=watermark_blocks,
                token_budget=token_budget, prefill_chunk=prefill_chunk,
                cache=self.prefix_cache,
            )
            self.cache = make_paged_cache(
                cfg, self.num_blocks, bs, max_slots, dtype=jnp.float32
            )
            self._prefill_paged, self._copy_block = _make_paged_fns(
                cfg, max_seq, bs, temperature
            )
            self._prefill_chunk_fn = (
                _make_chunk_fn(cfg, temperature)
                if self.sched.chunked_mode else None
            )
        else:
            assert not prefix_cache and prefill_chunk is None, \
                "prefix cache / chunked prefill require paged=True"
            self.cache = make_cache(cfg, max_slots, max_seq,
                                    dtype=jnp.float32)
            self._prefill, self._decode_1 = _make_fns(cfg, temperature)
        # ONE decode recurrence for both cache layouts (bt=None contiguous)
        self._decode_n = _make_decode_loop(
            cfg, max_seq, steps_per_sync, temperature
        )

    def submit(self, req: Request):
        # An empty prompt has no position to sample a first token from:
        # the legacy prefill would crash and a chunked admission would
        # wedge its slot in a zero-token prefill — reject it loudly.
        if not req.prompt:
            raise ValueError(f"request {req.rid} has an empty prompt")
        self.queue.append(req)

    @property
    def load(self) -> int:
        return len(self.queue) + sum(s is not None for s in self.slots)

    @property
    def free_slots(self) -> int:
        return sum(s is None for s in self.slots)

    @property
    def pool_occupancy(self) -> float:
        """Memory-pressure signal for the replica balancer: fraction of
        KV capacity in use (paged: live pool blocks; contiguous: busy
        slots — each slot is a full max_seq reservation)."""
        if self.paged:
            return self.pool.occupancy
        return 1.0 - self.free_slots / self.max_slots

    def can_accept(self) -> bool:
        """Whether one more typical admission fits right now: a free slot
        and, for paged caches, the scheduler's own admission predicate
        for a prompt-bucket-sized request (one policy, no drift)."""
        if self.free_slots == 0:
            return False
        if not self.paged:
            return True
        return self.sched.can_admit(
            self.pad_len, all(s is None for s in self.slots)
        )

    def _admit(self):
        for i in range(self.max_slots):
            if self.slots[i] is None and self.queue:
                req = self.queue.popleft()
                true_len = min(len(req.prompt), self.pad_len)
                toks = np.zeros((1, self.pad_len), np.int32)
                toks[0, :true_len] = req.prompt[:true_len]
                self._key, sub = jax.random.split(self._key)
                first, self.cache, self._row = self._prefill(
                    self.params, jnp.asarray(toks), self.cache, i,
                    self._row, true_len, sub,
                )
                first = int(first)          # one sync per admission
                self.host_syncs += 1
                req.out.append(first)
                self.slots[i] = req
                self.lens[i] = true_len
                self.budget[i] = req.max_new
                self.tokens[i, 0] = first
                self.tokens_out += 1

    def _finish_check(self, i: int, req: Request):
        if (len(req.out) > req.max_new
                or self.lens[i] >= self.max_seq - 1
                or self.budget[i] <= 0):
            req.done = True
            if self.paged and self.prefix_cache is not None:
                # Thread the written prefix into the radix cache BEFORE
                # freeing: the tree takes refs, free drops the seq's, and
                # the cached blocks survive at refcount 1 (reclaimable).
                toks = (list(req.prompt[: self.pad_len])
                        + list(req.out[:-1]))[: int(self.lens[i])]
                self.prefix_cache.insert(
                    toks, self.pool.block_table(req.rid), int(self.lens[i])
                )
            self.slots[i] = None
            self.lens[i] = -1
            self.budget[i] = 0
            if self.paged:
                self.sched.release(req.rid)
                self.sched.slot_released(i)

    def _drain(self, buf: np.ndarray):
        """Extend per-request outputs from the (N, slots) token buffer and
        mirror the device lens/budget recurrence on the host. Mid-prefill
        slots emitted nothing and still owe chunks — their finish checks
        (budget == 0 would misread as done) are skipped."""
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            if self.paged and self.sched.mid_prefill(i):
                continue
            toks = buf[:, i]
            toks = toks[toks >= 0]
            req.out.extend(int(t) for t in toks)
            n = len(toks)
            if n:
                self.tokens[i, 0] = toks[-1]
            self.lens[i] += n
            self.budget[i] -= n
            self.tokens_out += n
            if self.paged and n:
                self.pool.advance(req.rid, int(self.lens[i]))
            self._finish_check(i, req)

    # ------------------------------------------------------------ paged path
    def _prefix_tokens(self, req: Request) -> List[int]:
        """Tokens an admission must have in cache before decoding: the
        (bucket-truncated) prompt, plus all-but-the-last generated token
        when resuming a preempted request (the last one is the next feed
        token). This is also the prefix-cache lookup key."""
        return list(req.prompt[: self.pad_len]) + list(req.out[:-1])

    def _arm_decode(self, slot: int, req: Request, first):
        """Make a slot decodable once its prefill has landed: a resumed
        request re-feeds its last generated token (its first ``first``
        was sampled before preemption); a fresh one syncs the prefill's
        sampled first token. The ONLY place the resume-budget and
        first-token bookkeeping live — the single-shot and chunked
        admission paths both call it, so they cannot drift."""
        if req.out:                     # resume after preemption
            self.tokens[slot, 0] = req.out[-1]
            self.budget[slot] = req.max_new - (len(req.out) - 1)
        else:
            first = int(first)          # one sync per fresh admission
            self.host_syncs += 1
            req.out.append(first)
            self.tokens[slot, 0] = first
            self.budget[slot] = req.max_new
            self.tokens_out += 1

    def _admit_paged(self, slot: int, req: Request):
        """Prefill a scheduler-admitted request into ``slot``. Fresh
        requests sample their first token from the prefill logits; a
        preempted request resumes by recomputing its cache from
        prompt + generated-so-far (greedy-token-identical to never having
        been preempted) and re-feeds its last generated token."""
        prefix = self._prefix_tokens(req)
        true_len = len(prefix)
        bucket = min(-(-true_len // self.pad_len) * self.pad_len,
                     self.max_seq)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :true_len] = prefix
        # Scatter table: physical blocks for the prefix, OOB sentinel for
        # everything past it (lookahead blocks are written by decode).
        table = self.pool.block_table(req.rid)
        n_pb = -(-true_len // self.block_size)
        bt_scatter = np.full(self.max_blocks, self.num_blocks, np.int32)
        bt_scatter[:n_pb] = table[:n_pb]
        self._key, sub = jax.random.split(self._key)
        first, self.cache, self._row = self._prefill_paged(
            self.params, jnp.asarray(toks), self.cache,
            jnp.asarray(bt_scatter), slot, self._row, true_len, sub,
        )
        self._arm_decode(slot, req, first)
        self.lens[slot] = true_len

    def _run_prefill_chunk(self, slot: int, req: Request, start: int,
                           end: int, last: bool):
        """Prefill tokens [start, end) of the slot's prefix straight into
        the pool blocks (exact shapes, no bucket padding — one retrace
        per distinct chunk length). On the final chunk the sequence
        becomes decodable: a fresh request samples its first token from
        the chunk's last logits; a resumed one re-feeds its last
        generated token."""
        prefix = self._prefix_tokens(req)
        toks = np.asarray([prefix[start:end]], np.int32)
        table = self.pool.block_table(req.rid)
        bt = np.full(self.max_blocks, self.num_blocks, np.int32)
        bt[: len(table)] = table
        self._key, sub = jax.random.split(self._key)
        first, self.cache = self._prefill_chunk_fn(
            self.params, jnp.asarray(toks), self.cache, jnp.asarray(bt),
            jnp.int32(start), sub,
        )
        self.pool.advance(req.rid, end)
        self.lens[slot] = end
        if not last:
            self.budget[slot] = 0           # not decodable yet
            return
        self._arm_decode(slot, req, first)

    def _device_tables(self) -> jax.Array:
        bt = np.zeros((self.max_slots, self.max_blocks), np.int32)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            t = self.pool.block_table(req.rid)
            bt[i, : len(t)] = t
        return jnp.asarray(bt)

    def _step_paged(self):
        plan = self.sched.plan_step(self.queue, self.slots, self.lens,
                                    self._prefix_tokens)
        for slot, _req in plan.preempted:
            self.lens[slot] = -1
            self.budget[slot] = 0
            self.tokens[slot, 0] = 0
        for src, dst in plan.copies:
            self.cache = self._copy_block(
                self.cache, jnp.int32(src), jnp.int32(dst)
            )
        for slot, req in plan.admit:
            self._admit_paged(slot, req)
        for slot, req, start, end, last in plan.prefill:
            self._run_prefill_chunk(slot, req, start, end, last)
        running = sum(s is not None for s in self.slots)
        self.peak_running = max(self.peak_running, running)
        s = self.pool.stats()
        self.peak_occupancy = max(self.peak_occupancy, s.occupancy)
        self.peak_fragmentation = max(self.peak_fragmentation,
                                      s.fragmentation)
        if running == 0:
            return
        if plan.active.any():
            step_lens = np.where(plan.active, self.lens,
                                 -1).astype(np.int32)
            # A partial reservation (watermark-starved pool) caps this
            # step's writes at the granted capacity, and plan.quota at
            # the slot's slice of the shared token budget; the real
            # budget is decremented by the drain, so the remainder
            # carries to the next step.
            cap_left = np.maximum(plan.granted - self.lens, 0)
            step_budget = np.where(
                plan.active,
                np.minimum(np.minimum(self.budget, cap_left), plan.quota),
                self.budget,
            ).astype(np.int32)
            buf, self.cache, self._key = self._decode_n(
                self.params, jnp.asarray(self.tokens), self.cache,
                self._device_tables(), jnp.asarray(step_lens),
                jnp.asarray(step_budget), self._key,
            )
            buf = np.asarray(buf)           # the single drain
            self.host_syncs += 1
            self._drain(buf)
        self.steps += 1

    # ------------------------------------------------------------------ step
    def step(self):
        """One engine iteration: admit, then `steps_per_sync` batched
        decode steps on device with ONE host drain at the end (idle slots
        carry lens=-1 and stay untouched). Paged engines delegate
        admission/preemption to the continuous-batching scheduler."""
        if self.paged:
            return self._step_paged()
        self._admit()
        if all(s is None for s in self.slots):
            return
        self.peak_running = max(
            self.peak_running, sum(s is not None for s in self.slots)
        )
        buf, self.cache, self._key = self._decode_n(
            self.params, jnp.asarray(self.tokens), self.cache, None,
            jnp.asarray(self.lens), jnp.asarray(self.budget), self._key,
        )
        buf = np.asarray(buf)               # the single drain
        self.host_syncs += 1
        self._drain(buf)
        self.steps += 1

    def step_legacy(self):
        """The pre-fast-path loop: ONE decode step and one host round-trip
        per token. Kept as the bench_serve / equivalence baseline."""
        assert not self.paged, "step_legacy is the contiguous baseline"
        self._admit()
        if all(s is None for s in self.slots):
            return
        nxt, self.cache = self._decode_1(
            self.params, jnp.asarray(self.tokens), self.cache,
            jnp.asarray(self.lens),
        )
        nxt = np.asarray(nxt)
        self.host_syncs += 1
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            tok = int(nxt[i])
            req.out.append(tok)
            self.tokens[i, 0] = tok
            self.lens[i] += 1
            self.budget[i] -= 1
            self.tokens_out += 1
            self._finish_check(i, req)
        self.steps += 1


class GLBReplicaBalancer:
    """GLB over replicas: queue depths are the size vector; hungry replicas
    steal queued requests via the deterministic matching.

    Hungry = "can admit more work right now": a free decode slot AND (for
    paged engines) free KV blocks above the watermark, with an empty local
    queue — so a replica under memory pressure never steals, and a busy
    replica with spare capacity does (it used to require total idleness).
    Steals drain the victim's queue oldest-first (FIFO), preserving
    arrival order for the stolen requests."""

    def __init__(self, engines: List[Engine],
                 params: GLBParams = GLBParams()):
        self.engines = engines
        self.params = params
        P = len(engines)
        z = params.resolve_z(P)
        self._buddies = jnp.asarray(lifeline_buddies(P, z))
        self._pending = jnp.zeros((P, P), bool)
        self._step = 0
        self._rr = 0                   # submission counter: placement must
                                       # not depend on rid density
        self.moves = 0

    def submit(self, req: Request, rr: Optional[int] = None):
        """Round-robin placement by an internal submission counter —
        ``rid % P`` skews badly when rids are strided or clustered (e.g.
        all-even rids land every request on replica 0 of 2). ``rr``
        overrides the counter for adversarial test placement."""
        if rr is None:
            i = self._rr % len(self.engines)
            self._rr += 1
        else:
            i = rr % len(self.engines)
        self.engines[i].submit(req)

    def balance(self):
        sizes = np.asarray([len(e.queue) for e in self.engines], np.int32)
        hungry = np.asarray(
            [e.can_accept() and len(e.queue) == 0 for e in self.engines]
        )
        m = match_steals(
            jnp.asarray(sizes), jnp.asarray(hungry), self._pending,
            jax.random.fold_in(jax.random.key(17), self._step),
            self._buddies, self.params,
        )
        self._pending = m.pending
        src = np.asarray(m.src)
        for thief, victim in enumerate(src):
            if victim < 0:
                continue
            v = self.engines[int(victim)]
            take = max(1, len(v.queue) // 2)
            for _ in range(min(take, len(v.queue))):
                # Oldest-first: stolen requests keep their arrival order
                # on the thief instead of inverting the victim's tail.
                self.engines[thief].submit(v.queue.popleft())
                self.moves += 1
        self._step += 1

    def run(self, max_steps: int = 10_000):
        while any(e.load > 0 for e in self.engines) and max_steps > 0:
            self.balance()
            for e in self.engines:
                e.step()
            max_steps -= 1
