"""Serving engine: continuous batching over decode slots + GLB request
balancing across replicas.

Each replica owns a fixed pool of decode slots (static shapes). New
requests prefill into a free slot (prompts padded to a bucket length,
KV/conv state written into a reused preallocated row cache — no
``make_cache`` allocation churn per admission); all active slots advance
``steps_per_sync`` tokens per engine step inside ONE jitted
``lax.fori_loop`` decode: sampling (greedy or temperature, device-side
PRNG key threading) happens on device, per-slot done masks gate cache
writes and length/budget accounting, and each step emits an
(N, slots) token buffer the host drains with a single device->host sync —
~N× fewer host round-trips than the per-token loop (kept as
``step_legacy`` for benchmarking). Per-slot cache lengths (-1 marks an
idle slot: its cache/state is untouched) flow through to the split-KV
flash-decode kernel.

``paged=True`` swaps the fixed contiguous per-slot KV rows for the paged
subsystem (DESIGN.md §7): attention caches become flat block pools
(``models.make_paged_cache``) mapped per sequence by ``serve.kvpool``,
every engine step is planned by the continuous-batching scheduler
(``serve.scheduler`` — lookahead block reservation, watermark-based
preempt-and-requeue of the youngest sequence, per-step token budget,
strict-FIFO admission), and decode attention walks the block table
through the paged flash-decode kernel (`ops.attention(...,
block_tables=)`). A preempted request resumes by recomputing its cache
from prompt + generated-so-far, so greedy outputs are token-identical to
an uninterrupted run.

``prefix_cache=True`` adds the radix prefix cache (DESIGN.md §8): a
finished sequence's full KV blocks stay in a radix tree over its tokens,
a new admission forks the longest cached prefix (zero recompute, COW on
the partial tail) and prefills only its suffix, and cached blocks are
evicted LRU on pool pressure. ``prefill_chunk=N`` splits long prefills
into N-token chunks charged against the step token budget and
interleaved with decode (the ``paged_prefill`` kernel attends chunk
[s, e) to pool window [0, e)), so a long admission no longer stalls
co-scheduled decodes for one giant forward. Both features are
attention-family only (recurrent conv/ssm state cannot be forked).

The multi-replica balancer treats per-replica load as the GLB size
vector and steals work from overloaded to hungry replicas with the same
deterministic matching the task scheduler uses — the paper's library
applied to serving (DESIGN.md §4/§6/§9). Stealing is two-tier: queued
(unstarted) requests move first; with ``migrate=True`` a victim whose
queue is empty but whose slots are saturated sheds *live* sequences —
their written KV blocks travel as a dense buffer (``KVPool.extract`` /
``inject``) and decoding resumes on the thief greedy-token-identically
(falling back to radix-seeded or plain resume-by-recompute when the
thief's pool is tight). Hungry means "has a free slot and free KV
blocks", so replicas steal on memory headroom, not only when fully idle.
Termination and result collection are GLB-style: the load vector the
matching already gathers detects completion, and per-replica stats merge
into one fabric report.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (GLBParams, diffusion_pairs, fabric_summary,
                        lifeline_buddies, match_steals, merge_place_stats,
                        rewire_lifelines, terminated)
from repro.core.autotune import paged_block_kv
from repro.models import (decode_step, forward, make_cache,
                          make_paged_cache, sample_tokens)
from repro.models.config import ModelConfig
from repro.obs import (DEFAULT_BYTE_BUCKETS, NULL_TRACER, MetricsRegistry,
                       now_us)

from .kvpool import KVPool, PoolExhausted
from .radix import RadixPrefixCache
from .scheduler import ContinuousBatchingScheduler


@dataclasses.dataclass
class Request:
    """One serving request: prompt tokens in, up to ``max_new`` decoded
    tokens out, plus the lifecycle stamps the observability and cost
    layers read. The same object travels with the request through
    steals, migrations, and crash re-admission — whoever holds it owns
    the request."""

    rid: int
    prompt: List[int]
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # Cost-model inputs (DESIGN.md §16): the tenant keys the per-tenant
    # decode-length histogram; predicted_decode is the at-submit length
    # prediction (-1 = never stamped), kept across re-submits like
    # t_submit so finish-time scoring judges the ORIGINAL prediction.
    tenant: str = ""
    predicted_decode: float = -1.0
    # Observability stamps (obs clock domain, µs): submission, the last
    # time the request entered a queue (submit / preempt / migrate
    # requeue), and the first output token (TTFT anchor).
    t_submit: float = 0.0
    t_queued: float = 0.0
    t_first: float = 0.0


@dataclasses.dataclass
class Migration:
    """A live sequence in flight between replicas (DESIGN.md §9). The
    victim's ``migrate_out`` owns the only copy of the request and its
    packed KV until the thief's ``migrate_in`` lands it — the victim has
    already freed its blocks and slot, so dropping a Migration loses the
    request. ``kv`` is the dense transfer buffer: k/v pool blocks
    covering exactly the WRITTEN tokens, in logical order (None for
    recurrent families, which resume by recompute)."""
    req: Request
    tokens: List[int]          # cache contents = prompt bucket + out[:-1]
    written: int               # == len(tokens), the cache fill level
    block_size: int
    kv: Optional[dict]         # {"k","v"}: np (layers, n, bs, heads, hd)


# Module-level jits (NOT per-engine closures): every engine with the same
# cache/buffer shapes shares one compiled gather/scatter, so a fabric of N
# replicas compiles the migration path once, not N times.
@jax.jit
def _gather_kv(cache_k, cache_v, ids):
    return cache_k[:, ids], cache_v[:, ids]


@jax.jit
def _scatter_kv(cache_k, cache_v, ids, bk, bv):
    return (cache_k.at[:, ids].set(bk.astype(cache_k.dtype)),
            cache_v.at[:, ids].set(bv.astype(cache_v.dtype)))


def _scrub_row(row):
    # The reused row cache carries the previous request's state.
    # Attention k/v tails are harmless (masked by cache length), but
    # recurrent conv/ssm state feeds prefill directly and must be zero.
    return {
        name: (leaf if name in ("k", "v") else jnp.zeros_like(leaf))
        for name, leaf in row.items()
    }


def _make_decode_loop(cfg: ModelConfig, max_seq: int, steps_per_sync: int,
                      temperature: float):
    """The jitted fori_loop fast path, shared by the contiguous and paged
    engines (``bt`` is the block table for paged caches, None for
    contiguous — one recurrence, so the done-mask/budget rules can never
    diverge between the two)."""
    vocab = cfg.vocab

    @jax.jit
    def decode_tokens(params, tokens, cache, bt, lens, budget, key):
        """steps_per_sync decode steps entirely on device. Carries per-slot
        done masks (idle: lens < 0; finished: budget == 0) and fills an
        (N, slots) token buffer (-1 where a slot emitted nothing) that the
        host drains with one sync."""
        B = tokens.shape[0]
        buf = jnp.full((steps_per_sync, B), -1, jnp.int32)

        def body(t, carry):
            tokens, cache, lens, budget, key, buf = carry
            active = (lens >= 0) & (budget > 0)
            step_lens = jnp.where(active, lens, -1)
            logits, cache = decode_step(params, cfg, tokens, cache,
                                        step_lens, block_tables=bt)
            key, sub = jax.random.split(key)
            nxt = sample_tokens(logits[:, 0, ..., :vocab], sub, temperature)
            nxt = jnp.where(active, nxt, -1)
            buf = buf.at[t].set(nxt)
            lens = jnp.where(active, lens + 1, lens)
            budget = jnp.where(active, budget - 1, budget)
            budget = jnp.where(lens >= max_seq - 1, 0, budget)  # cache full
            tokens = jnp.where(active[:, None], nxt[:, None], tokens)
            return tokens, cache, lens, budget, key, buf

        carry = (tokens, cache, lens, budget, key, buf)
        tokens, cache, lens, budget, key, buf = jax.lax.fori_loop(
            0, steps_per_sync, body, carry
        )
        return buf, cache, key

    return decode_tokens


def _make_fns(cfg: ModelConfig, temperature: float):
    vocab = cfg.vocab

    @jax.jit
    def prefill_into_slot(params, tokens, cache, slot, row, true_len, key):
        logits, row, _ = forward(
            params, cfg, tokens=tokens, cache=_scrub_row(row),
            cache_len=jnp.int32(0), mode="prefill",
        )
        def put(c, r):
            start = (0, slot) + (0,) * (c.ndim - 2)
            return jax.lax.dynamic_update_slice(c, r.astype(c.dtype), start)
        cache = jax.tree.map(put, cache, row)
        first = sample_tokens(
            logits[0, true_len - 1, ..., :vocab], key, temperature
        )
        return first, cache, row

    @jax.jit
    def decode_one(params, tokens, cache, lens):
        # Pre-fast-path decode: one step, greedy, logits -> host argmax is
        # the caller's job historically; argmax stays on device here but
        # the loop still syncs every token (step_legacy baseline).
        logits, cache = decode_step(params, cfg, tokens, cache, lens)
        nxt = jnp.argmax(logits[:, 0, ..., :vocab], axis=-1)
        return nxt.astype(jnp.int32), cache

    return prefill_into_slot, decode_one


def _make_paged_fns(cfg: ModelConfig, max_seq: int, block_size: int,
                    temperature: float):
    vocab = cfg.vocab
    max_blocks = max_seq // block_size

    @jax.jit
    def prefill_paged(params, tokens, cache, bt_scatter, slot, row,
                      true_len, key):
        """Prefill into the reused row cache, then scatter the row's KV
        blocks into the pool through ``bt_scatter`` ((max_blocks,) i32,
        out-of-bounds sentinel past the prompt's blocks => dropped).
        Recurrent conv/ssm leaves stay slot-dense and write at ``slot``.
        Retraces once per prompt bucket length (tokens.shape[1])."""
        logits, row, _ = forward(
            params, cfg, tokens=tokens, cache=_scrub_row(row),
            cache_len=jnp.int32(0), mode="prefill",
        )

        def put(name, c, r):
            if name in ("k", "v"):
                na = c.shape[0]
                rb = r[:, 0].reshape(
                    na, max_blocks, block_size, c.shape[-2], c.shape[-1]
                )
                return c.at[:, bt_scatter].set(rb.astype(c.dtype),
                                               mode="drop")
            start = (0, slot) + (0,) * (c.ndim - 2)
            return jax.lax.dynamic_update_slice(c, r.astype(c.dtype), start)

        cache = {name: put(name, cache[name], row[name]) for name in cache}
        first = sample_tokens(
            logits[0, true_len - 1, ..., :vocab], key, temperature
        )
        return first, cache, row

    @jax.jit
    def copy_block(cache, src, dst):
        """Apply one COW copy: physical block dst := src in the k/v
        pools (recurrent slot state is never shared, nothing to copy)."""
        out = dict(cache)
        for name in ("k", "v"):
            if name in cache:
                out[name] = cache[name].at[:, dst].set(cache[name][:, src])
        return out

    return prefill_paged, copy_block


def _make_chunk_fn(cfg: ModelConfig, temperature: float):
    """Chunked-prefill forward: tokens [start, start+C) of one sequence,
    writing k/v straight into the pool blocks through the block table and
    attending to the paged window [0, start+C) (paged_prefill kernel /
    oracle). Chunk shapes are exact (no bucket padding), so this retraces
    once per distinct chunk length. Returns the sampled token from the
    chunk's last position — callers use it only on the final chunk."""
    vocab = cfg.vocab

    @jax.jit
    def prefill_chunk(params, tokens, cache, bt, start, key):
        logits, cache, _ = forward(
            params, cfg, tokens=tokens, cache=cache, cache_len=start,
            mode="prefill", block_tables=bt[None, :],
        )
        last = sample_tokens(logits[0, -1, ..., :vocab], key, temperature)
        return last, cache

    return prefill_chunk


class Engine:
    """One serving replica: continuous batching over a fixed pool of
    decode slots, jitted multi-token decode between host syncs, and —
    with ``paged=True`` — the paged KV subsystem (block pool, scheduler,
    radix prefix cache, chunked prefill, live migration). See the module
    docstring for the architecture; a fabric of Engines is driven by
    :class:`GLBReplicaBalancer`."""

    def __init__(self, cfg: ModelConfig, params, max_slots: int = 4,
                 max_seq: int = 256, pad_len: int = 32,
                 steps_per_sync: int = 8, temperature: float = 0.0,
                 seed: int = 0, paged: bool = False,
                 block_size: Optional[int] = None,
                 num_blocks: Optional[int] = None,
                 watermark_blocks: int = 0,
                 token_budget: Optional[int] = None,
                 prefix_cache: bool = False,
                 prefill_chunk: Optional[int] = None,
                 shed_policy: str = "youngest",
                 tracer=None, metrics=None, slo=None,
                 slo_admission: bool = False, cost_model=None,
                 replica_id: int = 0):
        self.cfg = cfg
        self.params = params
        # Observability (DESIGN.md §10): tracer defaults to the no-op
        # NullTracer — every emit site guards on `.enabled`, so the
        # disabled hot path pays one attribute check. The metrics
        # registry is always real (per-request observations only, never
        # per token); `stats()` is a view over it. A fabric shares ONE
        # tracer (request spans cross replicas) but each replica keeps
        # its own registry, merged at result collection.
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        # Optional SLOMonitor (obs.slo): fed the same per-request
        # latencies the histograms get; one monitor is shared fabric-wide
        # the way the tracer is.
        self.slo = slo
        # Optional CostModel (serve.cost, DESIGN.md §16): stamps a
        # decode-length prediction at submit and scores it at finish.
        # Shared fabric-wide like the tracer/SLO monitor; None costs one
        # attribute check per request boundary.
        self.cost_model = cost_model
        if slo_admission and not paged:
            raise ValueError("slo_admission needs the paged scheduler")
        self.replica_id = replica_id
        if self.tracer.enabled:
            self.tracer.process_name(replica_id, f"replica {replica_id}")
            self.tracer.thread_name(replica_id, 0, "engine")
        self.max_slots = max_slots
        self.max_seq = max_seq
        self.pad_len = pad_len
        self.steps_per_sync = steps_per_sync
        self.paged = paged
        self.prefix_cache = None       # set below for paged engines
        self.queue: deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * max_slots
        self.lens = np.full(max_slots, -1, np.int32)    # -1 => idle slot
        self.budget = np.zeros(max_slots, np.int32)     # tokens still owed
        self._row = make_cache(cfg, 1, max_seq, dtype=jnp.float32)
        self.tokens = np.zeros((max_slots, 1), np.int32)
        self._key = jax.random.key(seed)
        self.steps = 0
        self.tokens_out = 0
        self.host_syncs = 0    # blocking device->host transfer points
        self.peak_running = 0  # max concurrent sequences observed
        self.peak_occupancy = 0.0   # paged: max pool occupancy observed
        self.peak_fragmentation = 0.0
        self.migrations_out = 0     # live sequences shipped to a peer
        self.migrations_in = 0      # live sequences landed with their KV
        self.migrations_seeded = 0  # landed via a planted radix prefix
        self.migrations_recompute = 0   # landed WITHOUT KV (recompute)
        self._seed_sid = -1         # temp seq ids for radix seeding
        if paged:
            bs = block_size or paged_block_kv(max_seq, cfg.hd)
            assert max_seq % bs == 0, (max_seq, bs)
            self.block_size = bs
            self.max_blocks = max_seq // bs
            self.num_blocks = num_blocks or max_slots * self.max_blocks
            assert self.num_blocks >= self.max_blocks, \
                "pool must fit at least one full-length sequence"
            self.pool = KVPool(self.num_blocks, bs)
            if prefix_cache or prefill_chunk is not None:
                # Recurrent conv/ssm state is not block-addressable: a
                # cached prefix (or an earlier chunk) carries hidden
                # state the pool cannot fork, so prefix reuse and
                # chunked prefill are attention-family features.
                assert cfg.family not in ("ssm", "hybrid"), (
                    "prefix cache / chunked prefill need stateless "
                    f"attention KV, not family={cfg.family!r}"
                )
            if prefix_cache:
                self.prefix_cache = RadixPrefixCache(
                    self.pool, tracer=self.tracer, pid=replica_id
                )
            self.sched = ContinuousBatchingScheduler(
                self.pool, max_slots, lookahead=steps_per_sync,
                max_seq=max_seq, watermark_blocks=watermark_blocks,
                token_budget=token_budget, prefill_chunk=prefill_chunk,
                cache=self.prefix_cache, shed_policy=shed_policy,
                tracer=self.tracer, metrics=self.metrics, slo=self.slo,
                slo_admission=slo_admission, cost_model=cost_model,
                pid=replica_id,
            )
            self.cache = make_paged_cache(
                cfg, self.num_blocks, bs, max_slots, dtype=jnp.float32
            )
            self._prefill_paged, self._copy_block = _make_paged_fns(
                cfg, max_seq, bs, temperature
            )
            self._prefill_chunk_fn = (
                _make_chunk_fn(cfg, temperature)
                if self.sched.chunked_mode else None
            )
        else:
            assert not prefix_cache and prefill_chunk is None, \
                "prefix cache / chunked prefill require paged=True"
            self.cache = make_cache(cfg, max_slots, max_seq,
                                    dtype=jnp.float32)
            self._prefill, self._decode_1 = _make_fns(cfg, temperature)
        # ONE decode recurrence for both cache layouts (bt=None contiguous)
        self._decode_n = _make_decode_loop(
            cfg, max_seq, steps_per_sync, temperature
        )

    def submit(self, req: Request):
        # An empty prompt has no position to sample a first token from:
        # the legacy prefill would crash and a chunked admission would
        # wedge its slot in a zero-token prefill — reject it loudly.
        if not req.prompt:
            raise ValueError(f"request {req.rid} has an empty prompt")
        # A stolen request is re-submitted on the thief: keep the
        # original submission stamp (TTFT measures from first submit) and
        # count it once, but restart its queue-wait clock.
        if not req.t_submit:
            req.t_submit = now_us()
            self.metrics.counter("requests_submitted").inc()
        req.t_queued = now_us()
        if self.tracer.enabled:
            self.tracer.req_begin(req.rid, pid=self.replica_id,
                                  args={"prompt_tokens": len(req.prompt),
                                        "max_new": req.max_new})
            self.tracer.req_phase(req.rid, "queued", pid=self.replica_id)
        if self.cost_model is not None:
            self.cost_model.stamp(req)
        self.queue.append(req)

    @property
    def load(self) -> int:
        return len(self.queue) + sum(s is not None for s in self.slots)

    # --------------------------------------------------------- cost model
    def request_cost(self, req: Request, queued: bool) -> float:
        """Predicted remaining block-seconds for one request ON THIS
        replica (requires a cost model). A queued request is priced at
        its full recompute prefix minus this replica's radix-cache hit
        length plus its predicted decode; a running one at its remaining
        decode only — so the same request is cheaper on a replica whose
        cache already holds its prefix, which is exactly the signal the
        diffusive balancer wants."""
        cm = self.cost_model
        bs = self.block_size if self.paged else self.max_seq
        if queued:
            ptoks = self._prefix_tokens(req)
            cached = (self.prefix_cache.hit_length(ptoks)
                      if self.prefix_cache is not None else 0)
            return cm.estimate(len(ptoks), cached, 0, req.tenant,
                               req.max_new, bs)
        return cm.estimate(min(len(req.prompt), self.pad_len), 0,
                           len(req.out), req.tenant, req.max_new, bs)

    @property
    def predicted_cost(self) -> float:
        """This replica's entry in the predictive load vector: summed
        predicted remaining block-seconds over its queue and running
        slots (0.0 without a cost model — the balancer falls back to
        integer counts)."""
        if self.cost_model is None:
            return 0.0
        cost = sum(self.request_cost(r, True) for r in self.queue)
        cost += sum(self.request_cost(r, False)
                    for r in self.slots if r is not None)
        return cost

    @property
    def free_slots(self) -> int:
        return sum(s is None for s in self.slots)

    @property
    def pool_occupancy(self) -> float:
        """Memory-pressure signal for the replica balancer: fraction of
        KV capacity in use (paged: live pool blocks; contiguous: busy
        slots — each slot is a full max_seq reservation)."""
        if self.paged:
            return self.pool.occupancy
        return 1.0 - self.free_slots / self.max_slots

    def can_accept(self) -> bool:
        """Whether one more typical admission fits right now: a free slot
        and, for paged caches, the scheduler's own admission predicate
        for a prompt-bucket-sized request (one policy, no drift)."""
        if self.free_slots == 0:
            return False
        if not self.paged:
            return True
        return self.sched.can_admit(
            self.pad_len, all(s is None for s in self.slots)
        )

    def _admit(self):
        for i in range(self.max_slots):
            if self.slots[i] is None and self.queue:
                req = self.queue.popleft()
                t_adm = now_us()
                if req.t_queued:
                    wait_ms = (t_adm - req.t_queued) / 1e3
                    self.metrics.histogram("queue_wait_ms").observe(
                        wait_ms
                    )
                    if self.slo is not None:
                        self.slo.observe("queue_wait_ms", wait_ms)
                if self.tracer.enabled:
                    self.tracer.req_phase(req.rid, "prefill",
                                          pid=self.replica_id,
                                          args={"slot": i})
                    self.tracer.begin("prefill", pid=self.replica_id,
                                      args={"rid": req.rid})
                true_len = min(len(req.prompt), self.pad_len)
                toks = np.zeros((1, self.pad_len), np.int32)
                toks[0, :true_len] = req.prompt[:true_len]
                self._key, sub = jax.random.split(self._key)
                first, self.cache, self._row = self._prefill(
                    self.params, jnp.asarray(toks), self.cache, i,
                    self._row, true_len, sub,
                )
                first = int(first)          # one sync per admission
                self.host_syncs += 1
                req.out.append(first)
                self.slots[i] = req
                self.lens[i] = true_len
                self.budget[i] = req.max_new
                self.tokens[i, 0] = first
                self.tokens_out += 1
                req.t_first = now_us()
                self.metrics.histogram("prefill_chunk_ms").observe(
                    (req.t_first - t_adm) / 1e3
                )
                if req.t_submit:
                    ttft_ms = (req.t_first - req.t_submit) / 1e3
                    self.metrics.histogram("ttft_ms").observe(ttft_ms)
                    if self.slo is not None:
                        self.slo.observe("ttft_ms", ttft_ms)
                if self.tracer.enabled:
                    self.tracer.end(pid=self.replica_id)
                    self.tracer.req_phase(req.rid, "decode",
                                          pid=self.replica_id)

    def _finish_check(self, i: int, req: Request):
        if (len(req.out) > req.max_new
                or self.lens[i] >= self.max_seq - 1
                or self.budget[i] <= 0):
            req.done = True
            t_fin = now_us()
            self.metrics.counter("requests_finished").inc()
            if req.t_first:
                # Steady-state decode pace: TTFT is excluded, and the
                # first token itself emits no inter-token gap.
                tpot_ms = ((t_fin - req.t_first) / 1e3
                           / max(len(req.out) - 1, 1))
                self.metrics.histogram("tpot_ms").observe(tpot_ms)
                if self.slo is not None:
                    self.slo.observe("tpot_ms", tpot_ms)
            if self.cost_model is not None:
                # Close the prediction loop: score the stamped estimate
                # and feed the actual length back into the per-tenant
                # histogram. The cost_sample instant is what the
                # analyzer's prediction-error attribution parses.
                err = self.cost_model.observe_finish(req)
                if self.tracer.enabled and err is not None:
                    self.tracer.instant(
                        "cost_sample", pid=self.replica_id,
                        args={"rid": req.rid, "tenant": req.tenant,
                              "predicted": round(req.predicted_decode, 1),
                              "actual": len(req.out),
                              "err": round(err, 1)})
            if self.tracer.enabled:
                self.tracer.req_end(req.rid, pid=self.replica_id,
                                    args={"tokens": len(req.out)})
            if self.paged and self.prefix_cache is not None:
                # Thread the written prefix into the radix cache BEFORE
                # freeing: the tree takes refs, free drops the seq's, and
                # the cached blocks survive at refcount 1 (reclaimable).
                toks = (list(req.prompt[: self.pad_len])
                        + list(req.out[:-1]))[: int(self.lens[i])]
                self.prefix_cache.insert(
                    toks, self.pool.block_table(req.rid), int(self.lens[i])
                )
            self.slots[i] = None
            self.lens[i] = -1
            self.budget[i] = 0
            if self.paged:
                self.sched.release(req.rid)
                self.sched.slot_released(i)

    def _drain(self, buf: np.ndarray):
        """Extend per-request outputs from the (N, slots) token buffer and
        mirror the device lens/budget recurrence on the host. Mid-prefill
        slots emitted nothing and still owe chunks — their finish checks
        (budget == 0 would misread as done) are skipped."""
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            if self.paged and self.sched.mid_prefill(i):
                continue
            toks = buf[:, i]
            toks = toks[toks >= 0]
            req.out.extend(int(t) for t in toks)
            n = len(toks)
            if n:
                self.tokens[i, 0] = toks[-1]
            self.lens[i] += n
            self.budget[i] -= n
            self.tokens_out += n
            if self.paged and n:
                self.pool.advance(req.rid, int(self.lens[i]))
            self._finish_check(i, req)

    # ------------------------------------------------------------ paged path
    def _prefix_tokens(self, req: Request) -> List[int]:
        """Tokens an admission must have in cache before decoding: the
        (bucket-truncated) prompt, plus all-but-the-last generated token
        when resuming a preempted request (the last one is the next feed
        token). This is also the prefix-cache lookup key."""
        return list(req.prompt[: self.pad_len]) + list(req.out[:-1])

    def _arm_decode(self, slot: int, req: Request, first):
        """Make a slot decodable once its prefill has landed: a resumed
        request re-feeds its last generated token (its first ``first``
        was sampled before preemption); a fresh one syncs the prefill's
        sampled first token. The ONLY place the resume-budget and
        first-token bookkeeping live — the single-shot and chunked
        admission paths both call it, so they cannot drift."""
        if req.out:                     # resume after preemption
            self.tokens[slot, 0] = req.out[-1]
            self.budget[slot] = req.max_new - (len(req.out) - 1)
            if self.tracer.enabled:
                self.tracer.req_instant(req.rid, "resumed",
                                        pid=self.replica_id,
                                        args={"slot": slot,
                                              "out": len(req.out)})
                self.tracer.req_phase(req.rid, "decode",
                                      pid=self.replica_id)
        else:
            first = int(first)          # one sync per fresh admission
            self.host_syncs += 1
            req.out.append(first)
            self.tokens[slot, 0] = first
            self.budget[slot] = req.max_new
            self.tokens_out += 1
            req.t_first = now_us()
            if req.t_submit:
                ttft_ms = (req.t_first - req.t_submit) / 1e3
                self.metrics.histogram("ttft_ms").observe(ttft_ms)
                if self.slo is not None:
                    self.slo.observe("ttft_ms", ttft_ms)
            if self.tracer.enabled:
                self.tracer.req_phase(req.rid, "decode",
                                      pid=self.replica_id)

    def _admit_paged(self, slot: int, req: Request):
        """Prefill a scheduler-admitted request into ``slot``. Fresh
        requests sample their first token from the prefill logits; a
        preempted request resumes by recomputing its cache from
        prompt + generated-so-far (greedy-token-identical to never having
        been preempted) and re-feeds its last generated token."""
        prefix = self._prefix_tokens(req)
        true_len = len(prefix)
        bucket = min(-(-true_len // self.pad_len) * self.pad_len,
                     self.max_seq)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :true_len] = prefix
        # Scatter table: physical blocks for the prefix, OOB sentinel for
        # everything past it (lookahead blocks are written by decode).
        table = self.pool.block_table(req.rid)
        n_pb = -(-true_len // self.block_size)
        bt_scatter = np.full(self.max_blocks, self.num_blocks, np.int32)
        bt_scatter[:n_pb] = table[:n_pb]
        self._key, sub = jax.random.split(self._key)
        t0 = now_us()
        if self.tracer.enabled:
            self.tracer.thread_name(self.replica_id, 1 + slot,
                                    f"slot {slot}")
            self.tracer.begin("prefill", pid=self.replica_id,
                              tid=1 + slot,
                              args={"rid": req.rid, "tokens": true_len})
        first, self.cache, self._row = self._prefill_paged(
            self.params, jnp.asarray(toks), self.cache,
            jnp.asarray(bt_scatter), slot, self._row, true_len, sub,
        )
        self._arm_decode(slot, req, first)
        if self.tracer.enabled:
            self.tracer.end(pid=self.replica_id, tid=1 + slot)
        self.metrics.histogram("prefill_chunk_ms").observe(
            (now_us() - t0) / 1e3
        )
        self.lens[slot] = true_len

    def _run_prefill_chunk(self, slot: int, req: Request, start: int,
                           end: int, last: bool):
        """Prefill tokens [start, end) of the slot's prefix straight into
        the pool blocks (exact shapes, no bucket padding — one retrace
        per distinct chunk length). On the final chunk the sequence
        becomes decodable: a fresh request samples its first token from
        the chunk's last logits; a resumed one re-feeds its last
        generated token."""
        prefix = self._prefix_tokens(req)
        toks = np.asarray([prefix[start:end]], np.int32)
        table = self.pool.block_table(req.rid)
        bt = np.full(self.max_blocks, self.num_blocks, np.int32)
        bt[: len(table)] = table
        self._key, sub = jax.random.split(self._key)
        t0 = now_us()
        if self.tracer.enabled:
            self.tracer.thread_name(self.replica_id, 1 + slot,
                                    f"slot {slot}")
            self.tracer.begin("prefill_chunk", pid=self.replica_id,
                              tid=1 + slot,
                              args={"rid": req.rid, "start": start,
                                    "end": end, "last": last})
        first, self.cache = self._prefill_chunk_fn(
            self.params, jnp.asarray(toks), self.cache, jnp.asarray(bt),
            jnp.int32(start), sub,
        )
        if self.tracer.enabled:
            self.tracer.end(pid=self.replica_id, tid=1 + slot)
        self.metrics.histogram("prefill_chunk_ms").observe(
            (now_us() - t0) / 1e3
        )
        self.pool.advance(req.rid, end)
        self.lens[slot] = end
        if not last:
            self.budget[slot] = 0           # not decodable yet
            return
        self._arm_decode(slot, req, first)

    # ------------------------------------------------------- live migration
    def can_host(self, written: int) -> bool:
        """Whether a migrated sequence with ``written`` cache tokens can
        run here at all: it needs at least one free position below
        ``max_seq`` to decode into (regardless of landing mode — even
        the recompute resume prefills the full prefix). The balancer
        checks this before shedding so an incompatible thief is never
        handed a Migration it cannot land."""
        return self.paged and written < self.max_seq

    def migratable_slots(self) -> List[int]:
        """Slots the balancer may shed, best victim first (the
        scheduler's shed policy). Empty for contiguous engines — they
        have no block-granular extract — and excludes mid-prefill slots."""
        if not self.paged:
            return []
        return self.sched.shed_candidates(self.slots, self.budget)

    def migrate_out(self, slot: int) -> Migration:
        """Ship the live sequence in ``slot`` to a peer replica: pack its
        written KV blocks into a dense transfer buffer (one gather, one
        host sync), free its blocks and slot here, and hand ownership of
        the request to the returned Migration. Greedy token identity is
        preserved because the buffer holds exactly the cache prefix
        positions [0, written) — the thief re-feeds the last generated
        token at position ``written``, just like a preemption resume.
        Mid-prefill slots are rejected: their KV is half-written and
        their chunk plan cannot move."""
        assert self.paged, "live migration needs the paged KV pool"
        req = self.slots[slot]
        assert req is not None, f"slot {slot} is idle"
        if self.sched.mid_prefill(slot):
            raise ValueError(
                f"slot {slot} is mid-prefill and cannot migrate"
            )
        tokens = self._prefix_tokens(req)
        written = int(self.lens[slot])
        assert len(tokens) == written, (len(tokens), written)
        t0 = now_us()
        if self.tracer.enabled:
            self.tracer.begin("migrate_out", pid=self.replica_id,
                              args={"rid": req.rid, "written": written})
        kv = None
        if self.cfg.family not in ("ssm", "hybrid"):
            blocks, _ = self.pool.extract(req.rid)
            ids = jnp.asarray(np.asarray(blocks, np.int32))
            bk, bv = _gather_kv(self.cache["k"], self.cache["v"], ids)
            kv = {"k": np.asarray(bk), "v": np.asarray(bv)}
            self.host_syncs += 1
        mig = Migration(req=req, tokens=tokens, written=written,
                        block_size=self.block_size, kv=kv)
        self.slots[slot] = None
        self.lens[slot] = -1
        self.budget[slot] = 0
        self.tokens[slot, 0] = 0
        self.sched.release(req.rid)
        self.sched.slot_released(slot)
        self.migrations_out += 1
        nbytes = (kv["k"].nbytes + kv["v"].nbytes) if kv else 0
        self.metrics.histogram("migrate_pack_ms").observe(
            (now_us() - t0) / 1e3
        )
        self.metrics.histogram(
            "migration_bytes", DEFAULT_BYTE_BUCKETS
        ).observe(nbytes)
        if self.tracer.enabled:
            self.tracer.end(pid=self.replica_id)
            self.tracer.req_instant(req.rid, "migrated_out",
                                    pid=self.replica_id,
                                    args={"written": written,
                                          "bytes": nbytes})
            # The request is in flight: the victim opens the migrate
            # phase, the thief's landing path closes it (span ownership
            # travels with the request, DESIGN.md §10).
            self.tracer.req_phase(req.rid, "migrate", pid=self.replica_id)
        return mig

    def _requeue_migrated(self, req: Request) -> None:
        # Front of the queue: the sequence was already running and must
        # not wait behind fresh arrivals (same rule as preemption).
        req.t_queued = now_us()
        if self.tracer.enabled:
            self.tracer.req_phase(req.rid, "queued", pid=self.replica_id)
        self.queue.appendleft(req)

    def migrate_in(self, mig: Migration) -> str:
        """Land a migrated sequence (span + landing metrics around
        :meth:`_migrate_in`, which picks the mode — see its docstring)."""
        t0 = now_us()
        if self.tracer.enabled:
            self.tracer.begin("migrate_in", pid=self.replica_id,
                              args={"rid": mig.req.rid,
                                    "written": mig.written})
        try:
            mode = self._migrate_in(mig)
        finally:
            if self.tracer.enabled:
                self.tracer.end(pid=self.replica_id)
        self.metrics.histogram("migrate_land_ms").observe(
            (now_us() - t0) / 1e3
        )
        if self.tracer.enabled:
            self.tracer.req_instant(mig.req.rid, "migrated_in",
                                    pid=self.replica_id,
                                    args={"mode": mode})
        return mode

    def _migrate_in(self, mig: Migration) -> str:
        """Land a migrated sequence. Three outcomes, best first:

        * ``"live"`` — a free slot and enough pool blocks: inject fresh
          blocks, scatter the transfer buffer into them, and adopt the
          sequence as a running slot (zero recompute);
        * ``"seeded"`` — the pool cannot fit the whole sequence, but a
          prefix cache exists: inject however many full blocks DO fit
          under a temporary seq id, seed them into the radix tree, and
          requeue — the resume-by-recompute admission then *hits* the
          planted prefix and recomputes only the suffix;
        * ``"recompute"`` — no KV came along (recurrent family), block
          sizes differ, or nothing fits: plain resume-by-recompute.

        Every path preserves greedy token identity — they differ only in
        how much prefill work the move costs."""
        assert self.paged, "live migration needs the paged KV pool"
        req = mig.req
        if not self.can_host(mig.written):
            # Requeueing here would wedge/crash a later admission (the
            # prefix cannot fit this engine's max_seq); the caller still
            # owns the Migration and must pick a compatible host.
            raise ValueError(
                f"sequence with {mig.written} cache tokens cannot run "
                f"under max_seq={self.max_seq}; check can_host() first"
            )
        # A block-size mismatch (or no KV: recurrent family) makes the
        # raw buffer unusable; degrade to resume-by-recompute.
        if mig.kv is None or mig.block_size != self.block_size:
            self._requeue_migrated(req)
            self.migrations_recompute += 1
            return "recompute"
        slot = next((i for i in range(self.max_slots)
                     if self.slots[i] is None), None)
        if slot is not None and self.pool.can_alloc(mig.written):
            try:
                table = self.pool.inject(req.rid, mig.written)
            except PoolExhausted:   # eviction under-delivered (pinned)
                table = None
            if table is not None:
                self._scatter_migrated(table, mig.kv)
                self.sched.adopt(slot)
                self.slots[slot] = req
                self.lens[slot] = mig.written
                # req.out is non-empty (mid-decode), so this takes the
                # resume branch — one bookkeeping path with preemption.
                self._arm_decode(slot, req, None)
                self.peak_running = max(
                    self.peak_running,
                    sum(s is not None for s in self.slots),
                )
                self.migrations_in += 1
                return "live"
        if self.prefix_cache is not None:
            full = mig.written // self.block_size
            fit = min(full, self.pool.available_blocks)
            if fit > 0:
                sid = self._seed_sid
                self._seed_sid -= 1
                seeded = fit * self.block_size
                try:
                    table = self.pool.inject(sid, seeded)
                except PoolExhausted:   # reclaimables pinned mid-evict
                    table = None
                if table is not None:
                    self._scatter_migrated(
                        table,
                        {n: b[:, :fit] for n, b in mig.kv.items()},
                    )
                    self.prefix_cache.seed(mig.tokens[:seeded], table,
                                           seeded)
                    self.pool.free(sid)  # tree refs keep blocks cached
                    self._requeue_migrated(req)
                    self.migrations_seeded += 1
                    return "seeded"
        self._requeue_migrated(req)
        self.migrations_recompute += 1
        return "recompute"

    def _scatter_migrated(self, table: List[int], kv: dict) -> None:
        ids = jnp.asarray(np.asarray(table, np.int32))
        self.cache = dict(self.cache)
        self.cache["k"], self.cache["v"] = _scatter_kv(
            self.cache["k"], self.cache["v"], ids,
            jnp.asarray(kv["k"]), jnp.asarray(kv["v"]),
        )

    def _device_tables(self) -> jax.Array:
        bt = np.zeros((self.max_slots, self.max_blocks), np.int32)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            t = self.pool.block_table(req.rid)
            bt[i, : len(t)] = t
        return jnp.asarray(bt)

    def _step_paged(self):
        plan = self.sched.plan_step(self.queue, self.slots, self.lens,
                                    self._prefix_tokens)
        for slot, _req in plan.preempted:
            self.lens[slot] = -1
            self.budget[slot] = 0
            self.tokens[slot, 0] = 0
        for src, dst in plan.copies:
            self.cache = self._copy_block(
                self.cache, jnp.int32(src), jnp.int32(dst)
            )
        for slot, req in plan.admit:
            self._admit_paged(slot, req)
        for slot, req, start, end, last in plan.prefill:
            self._run_prefill_chunk(slot, req, start, end, last)
        running = sum(s is not None for s in self.slots)
        self.peak_running = max(self.peak_running, running)
        s = self.pool.stats()
        self.peak_occupancy = max(self.peak_occupancy, s.occupancy)
        self.peak_fragmentation = max(self.peak_fragmentation,
                                      s.fragmentation)
        if running == 0:
            return
        if plan.active.any():
            step_lens = np.where(plan.active, self.lens,
                                 -1).astype(np.int32)
            # A partial reservation (watermark-starved pool) caps this
            # step's writes at the granted capacity, and plan.quota at
            # the slot's slice of the shared token budget; the real
            # budget is decremented by the drain, so the remainder
            # carries to the next step.
            cap_left = np.maximum(plan.granted - self.lens, 0)
            step_budget = np.where(
                plan.active,
                np.minimum(np.minimum(self.budget, cap_left), plan.quota),
                self.budget,
            ).astype(np.int32)
            buf, self.cache, self._key = self._decode_n(
                self.params, jnp.asarray(self.tokens), self.cache,
                self._device_tables(), jnp.asarray(step_lens),
                jnp.asarray(step_budget), self._key,
            )
            buf = np.asarray(buf)           # the single drain
            self.host_syncs += 1
            self._drain(buf)
        self.steps += 1

    # ------------------------------------------------------------------ step
    def step(self):
        """One engine iteration: admit, then `steps_per_sync` batched
        decode steps on device with ONE host drain at the end (idle slots
        carry lens=-1 and stay untouched). Paged engines delegate
        admission/preemption to the continuous-batching scheduler.
        Traced runs wrap the iteration in an ``engine_step`` span and
        emit load/pool counter tracks; the untraced path dispatches
        straight to the implementation (one attribute check)."""
        if not self.tracer.enabled:
            return self._step_impl()
        with self.tracer.span("engine_step", pid=self.replica_id,
                              args={"step": self.steps}):
            self._step_impl()
            self.tracer.counter(
                "load",
                {"running": float(sum(s is not None for s in self.slots)),
                 "queued": float(len(self.queue))},
                pid=self.replica_id,
            )
            if self.paged:
                ps = self.pool.stats()
                self.tracer.counter(
                    "pool",
                    {"occupancy_pct": round(100 * ps.occupancy, 2),
                     "available_blocks": float(self.pool.available_blocks),
                     "watermark": float(self.sched.watermark)},
                    pid=self.replica_id,
                )

    def _step_impl(self):
        if self.paged:
            return self._step_paged()
        self._admit()
        if all(s is None for s in self.slots):
            return
        self.peak_running = max(
            self.peak_running, sum(s is not None for s in self.slots)
        )
        buf, self.cache, self._key = self._decode_n(
            self.params, jnp.asarray(self.tokens), self.cache, None,
            jnp.asarray(self.lens), jnp.asarray(self.budget), self._key,
        )
        buf = np.asarray(buf)               # the single drain
        self.host_syncs += 1
        self._drain(buf)
        self.steps += 1

    def step_legacy(self):
        """The pre-fast-path loop: ONE decode step and one host round-trip
        per token. Kept as the bench_serve / equivalence baseline."""
        assert not self.paged, "step_legacy is the contiguous baseline"
        self._admit()
        if all(s is None for s in self.slots):
            return
        nxt, self.cache = self._decode_1(
            self.params, jnp.asarray(self.tokens), self.cache,
            jnp.asarray(self.lens),
        )
        nxt = np.asarray(nxt)
        self.host_syncs += 1
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            tok = int(nxt[i])
            req.out.append(tok)
            self.tokens[i, 0] = tok
            self.lens[i] += 1
            self.budget[i] -= 1
            self.tokens_out += 1
            self._finish_check(i, req)
        self.steps += 1

    def stats(self) -> dict:
        """Per-replica counters for fabric-level result collection
        (``core.stats.merge_place_stats``). Numeric-only, flat — the
        union across heterogeneous replicas merges field-wise.

        A view over the metrics registry (DESIGN.md §10): engine /
        scheduler / prefix-cache attribute counters sync into gauges
        (idempotent ``set``, so repeated calls never double-count) and
        the returned dict is the registry snapshot — which also carries
        the live request histograms (``ttft_ms_*``, ``tpot_ms_*``,
        ``queue_wait_ms_*``, ...) and counters observed at request
        boundaries. One source of truth; no drift between ``stats()``,
        ``collect()``, and a Prometheus scrape."""
        m = self.metrics
        sync = dict(
            tokens_out=self.tokens_out,
            steps=self.steps,
            host_syncs=self.host_syncs,
            peak_running=self.peak_running,
            migrations_out=self.migrations_out,
            migrations_in=self.migrations_in,
            migrations_seeded=self.migrations_seeded,
            migrations_recompute=self.migrations_recompute,
        )
        if self.paged:
            sync.update(
                admissions=self.sched.admissions,
                preemptions=self.sched.preemptions,
                adoptions=self.sched.adoptions,
                chunks_scheduled=self.sched.chunks_scheduled,
                peak_occupancy_pct=round(100 * self.peak_occupancy, 1),
            )
        if self.prefix_cache is not None:
            pc = self.prefix_cache
            sync.update(
                cache_hits=pc.hits,
                cache_misses=pc.misses,
                tokens_reused=pc.tokens_reused,
                cache_evictions=pc.evictions,
                seeded_tokens=pc.seeded_tokens,
                # The one canonical hit-rate field (previously computed
                # ad hoc with different names in benches and examples).
                prefix_hit_rate_pct=round(100 * pc.hit_rate, 1),
            )
        for name, v in sync.items():
            m.gauge(name).set(v)
        return m.snapshot()


class GLBReplicaBalancer:
    """GLB over replicas — the paper's two-tier lifeline protocol applied
    to serving (DESIGN.md §9): steal *unstarted* work first, then *work
    in progress*.

    Per balance pass the per-replica loads are the GLB size vector and
    hungry replicas are matched to victims by the same deterministic
    lifeline matching the task scheduler uses (``core.lifeline``). A
    matched thief steals in two tiers:

    * **tier 1 — queued requests**: drained from the victim's queue
      oldest-first (FIFO), preserving arrival order;
    * **tier 2 — live sequences** (``migrate=True``, paged engines): when
      the victim's queue is empty but its slots are saturated, the
      victim's shed policy picks running sequences and their KV state
      migrates block-for-block (``Engine.migrate_out`` →
      ``Engine.migrate_in``) — the paper's "steal work in progress", so a
      replica wedged on long-running sequences can still shed load. The
      victim always keeps at least one running sequence (a bare handoff
      helps nobody).

    Hungry = "can admit more work right now": a free decode slot AND (for
    paged engines) free KV blocks above the watermark, with an empty local
    queue — so a replica under memory pressure never steals, and a busy
    replica with spare capacity does.

    Termination is GLB-style: the load vector gathered for the matching
    doubles as the termination detector (``core.lifeline.terminated`` —
    all loads zero), so ``run`` has no second polling loop over the
    engines; ``collect`` merges per-replica stats into the fabric-level
    result (the paper's hidden termination + result collection, §2.4).

    Failure semantics (DESIGN.md §15): the same load-vector gather is the
    heartbeat. With a ``faults`` injector attached, a replica that misses
    ``heartbeat_misses`` consecutive gathers is declared dead — fenced
    forever (never stepped again, even if it later wakes), its lifelines
    re-wired over the survivors (``core.rewire_lifelines``), its pending
    rows/columns cleared — and its lost requests are re-admitted from the
    balancer's submission ledger: queued casualties re-enter a survivor's
    queue, running casualties land as recompute resumes (the PR 5
    migration mode with ``kv=None``). While a replica is unresponsive but
    not yet declared dead its last-known load stands in, so a wedged
    replica holding all remaining work can never trigger spurious
    termination."""

    def __init__(self, engines: List[Engine],
                 params: GLBParams = GLBParams(),
                 migrate: bool = False, tracer=None, slo=None,
                 faults=None, heartbeat_misses: Optional[int] = None,
                 cost_model=None, predictive: bool = False,
                 imbalance_threshold: float = 0.25):
        self.engines = engines
        self.params = params
        self.migrate = migrate
        self.faults = faults
        # Predictive, cost-modeled balancing (DESIGN.md §16): with a
        # cost model attached the load vector can become predicted
        # block-seconds and a diffusive pre-pass moves work while any
        # replica exceeds the mean by ``imbalance_threshold`` — BEFORE
        # starvation fires; the reactive lifeline path below stays as
        # the backstop. predictive=False is the reactive-parity
        # contract: every decision site runs the exact pre-cost code
        # path (the model then only stamps/scores predictions).
        if predictive and cost_model is None:
            raise ValueError("predictive balancing requires a cost_model")
        self.cost_model = cost_model
        self.predictive = predictive
        self.imbalance_threshold = imbalance_threshold
        self.diffusion_moves = 0       # moves made by the diffusive pass
        # Decision log: one tuple per steal/shed/diffusion decision, in
        # execution order — the reactive-parity regression and the bench
        # parity row compare these across balancer configurations.
        self.decisions: List[tuple] = []
        if cost_model is not None:
            for e in engines:
                if e.cost_model is None:
                    e.cost_model = cost_model
        self.heartbeat_misses = (heartbeat_misses if heartbeat_misses
                                 is not None else params.heartbeat_misses)
        # Fabric-level trace track: supersteps, the load vector, steal
        # and termination instants live on their own pid, one past the
        # highest replica id (replica tracks keep their own pids).
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self._fabric_pid = 1 + max(
            (e.replica_id for e in engines), default=-1
        )
        if self.tracer.enabled:
            self.tracer.process_name(self._fabric_pid, "fabric balancer")
            self.tracer.thread_name(self._fabric_pid, 0, "balance")
        # SLO monitor (obs.slo): attach it to every engine that doesn't
        # have its own, bind the fabric tracer/pid for burn-rate
        # instants, and check() it once per balance pass.
        self.slo = slo
        if slo is not None:
            slo.bind(tracer=self.tracer, pid=self._fabric_pid)
            for e in engines:
                if e.slo is None:
                    e.slo = slo
                    if e.paged:
                        e.sched.slo = slo
        P = len(engines)
        z = params.resolve_z(P)
        self._buddies = jnp.asarray(lifeline_buddies(P, z))
        self._pending = jnp.zeros((P, P), bool)
        self._step = 0
        self._rr = 0                   # submission counter: placement must
                                       # not depend on rid density
        self.queue_moves = 0           # tier-1: queued requests stolen
        self.migrations = 0            # tier-2: live sequences migrated
        self.sterile_steals = 0        # matched pairs where nothing moved
        self.migration_modes = {"live": 0, "seeded": 0, "recompute": 0}
        self.supersteps = 0
        self.terminated = False
        # --------------------------- failure detection / recovery state
        self.metrics = MetricsRegistry()
        self._alive = [True] * P
        self._misses = [0] * P          # consecutive missed heartbeats
        self._last_load = [0] * P       # load at last answered gather
        self._last_cost = [0.0] * P     # predicted cost, same stand-in rule
        self._ledger: dict = {}         # rid -> Request, every submission
        self.replicas_dead = 0
        self.readmitted_queued = 0
        self.readmitted_running = 0

    @property
    def moves(self) -> int:
        """Total requests moved between replicas (both tiers). Tier-1
        queue steals and tier-2 live migrations are counted separately
        (``queue_moves`` / ``migrations``) — this is their sum, never a
        double-count."""
        return self.queue_moves + self.migrations

    @property
    def alive(self) -> List[bool]:
        return list(self._alive)

    def submit(self, req: Request, rr: Optional[int] = None):
        """Round-robin placement by an internal submission counter —
        ``rid % P`` skews badly when rids are strided or clustered (e.g.
        all-even rids land every request on replica 0 of 2). ``rr``
        overrides the counter for adversarial test placement.

        Every submission is recorded in the recovery ledger: if the
        hosting replica later dies, the ledger (minus finished requests
        and requests observed live on survivors) is exactly the lost
        set. Placement only considers replicas still alive."""
        self._ledger[req.rid] = req
        alive = [i for i in range(len(self.engines)) if self._alive[i]]
        if not alive:
            raise RuntimeError("replica fabric has no surviving replica")
        if rr is None:
            i = alive[self._rr % len(alive)]
            self._rr += 1
        else:
            i = alive[rr % len(alive)]
        self.engines[i].submit(req)
        # Keep the stand-in load fresh: a submission is balancer-local
        # knowledge, not something a heartbeat needs to discover.
        self._last_load[i] = self.engines[i].load

    def _stealable(self, e: Engine, thieves: List[Engine]) -> int:
        """One replica's entry in the GLB size vector: its queue depth,
        or — migration tier — its shed-candidate count when the queue is
        empty but every slot is busy (minus the one sequence a victim
        always keeps).

        The migration-tier count only includes candidates at least one
        currently-hungry thief ``can_host`` — advertised load must be
        load that can actually move. The unfiltered count made a victim
        whose only hungry peer is incompatible (block-size/max_seq
        mismatch) advertise forever, producing a sterile steal match
        every superstep that starved other edges of the matching."""
        q = len(e.queue)
        if q:
            return q
        if self.migrate and e.paged and e.free_slots == 0:
            cands = [s for s in e.migratable_slots()
                     if any(t.can_host(int(e.lens[s])) for t in thieves
                            if t is not e)]
            return max(len(cands) - 1, 0)
        return 0

    def _steal_live(self, thief: Engine, victim: Engine) -> None:
        """Tier 2: migrate running sequences victim -> thief. Takes up to
        half of what the victim can shed (the GLB steal-half rule), one
        per free thief slot; ``migrate_in`` decides per sequence whether
        it lands live, radix-seeded, or as a recompute resume."""
        cands = [s for s in victim.migratable_slots()
                 if thief.can_host(int(victim.lens[s]))]
        if self.predictive:
            # Cost-weighted shedding: move the sequences with the most
            # predicted work left (rid tie-break), not the shed policy's
            # cheapest-transfer order — maximizing offloaded block-
            # seconds per migration. Predictive mode only; the default
            # path keeps the policy order bit-for-bit.
            cands = sorted(
                cands,
                key=lambda s: (-victim.request_cost(victim.slots[s],
                                                    False),
                               victim.slots[s].rid))
        running = sum(s is not None for s in victim.slots)
        sheddable = max(len(cands) - 1, 0)      # victim keeps one running
        # GLB steal-half: ship half the victim's running set, bounded by
        # what it may shed and the slots the thief can absorb into.
        take = min(running // 2, sheddable, thief.free_slots)
        if take == 0:
            # A matched edge that moved nothing: the size vector promised
            # load this thief cannot absorb. _stealable()'s hungry-aware
            # filter makes this unreachable for single-thief fabrics;
            # counted so tests (and ops) can see residual mismatches.
            self.sterile_steals += 1
        for slot in cands[:take]:
            rid = victim.slots[slot].rid
            mode = thief.migrate_in(victim.migrate_out(slot))
            self.migrations += 1
            self.migration_modes[mode] += 1
            self.decisions.append(("live", victim.replica_id,
                                   thief.replica_id, rid, mode))
            if self.tracer.enabled:
                self.tracer.instant(
                    "steal_live", pid=self._fabric_pid,
                    args={"victim": victim.replica_id,
                          "thief": thief.replica_id, "mode": mode},
                )

    # ------------------------------------------- predictive diffusion
    def _fabric_costs(self) -> np.ndarray:
        """The predictive load vector: per-replica summed predicted
        block-seconds, gathered with the same stand-in rule as the
        integer loads (an unresponsive replica's last-known cost holds;
        a dead one reads 0)."""
        costs = np.zeros(len(self.engines))
        for i, e in enumerate(self.engines):
            if not self._alive[i]:
                continue
            if not self._responsive(i):
                costs[i] = self._last_cost[i]
                continue
            self._last_cost[i] = e.predicted_cost
            costs[i] = self._last_cost[i]
        return costs

    def _diffuse(self, active: List[bool]) -> None:
        """The diffusive pre-pass (DESIGN.md §16): pair replicas whose
        predicted cost exceeds the fabric mean by ``imbalance_threshold``
        with under-mean recipients (``core.diffusion_pairs``) and move
        work toward the mean — queued requests chosen greedily to
        minimize post-move cost imbalance, then at most one live
        sequence per pair as the tier-2 analogue. Runs BEFORE the
        reactive matching each pass, so starvation-driven stealing
        remains the backstop for whatever the predictions miss."""
        costs = self._fabric_costs()
        if self.tracer.enabled:
            self.tracer.counter(
                "fabric_cost",
                {f"replica{i}": round(float(c), 3)
                 for i, c in enumerate(costs)},
                pid=self._fabric_pid,
            )
        eligible = np.asarray(
            [active[i] and self.engines[i].can_accept()
             for i in range(len(self.engines))]
        )
        pairs = diffusion_pairs(costs, self.imbalance_threshold, eligible)
        mean = float(costs.mean())
        for d, r in pairs:
            if active[d]:
                self._diffuse_pair(d, r, costs, mean)

    def _diffuse_pair(self, d: int, r: int, costs: np.ndarray,
                      mean: float) -> None:
        """Move work donor ``d`` → recipient ``r`` until the donor drops
        back under the diffusion threshold: queued requests first (each
        pick minimizes ``|donor-mean| + |recipient-mean|`` after the
        move, rid tie-break, and a move must strictly improve it), then
        at most one live sequence when the donor's queue had nothing to
        give. Cost updates are local to the gathered vector — the next
        pass re-gathers from the engines."""
        donor, recip = self.engines[d], self.engines[r]
        hi = mean * (1.0 + self.imbalance_threshold)
        moved = 0
        while donor.queue and costs[d] > hi and recip.can_accept():
            cur = abs(costs[d] - mean) + abs(costs[r] - mean)
            best = best_c = None
            best_key = None
            for req in donor.queue:
                c = donor.request_cost(req, True)
                gain = cur - (abs(costs[d] - c - mean)
                              + abs(costs[r] + c - mean))
                key = (gain, -req.rid)
                if gain > 1e-9 and (best_key is None or key > best_key):
                    best, best_c, best_key = req, c, key
            if best is None:
                break
            donor.queue.remove(best)
            recip.submit(best)
            costs[d] -= best_c
            costs[r] += best_c
            self.queue_moves += 1
            self.diffusion_moves += 1
            moved += 1
            self.decisions.append(("diffuse", d, r, best.rid))
            if self.tracer.enabled:
                self.tracer.instant(
                    "diffuse_queued", pid=self._fabric_pid,
                    args={"donor": donor.replica_id,
                          "recipient": recip.replica_id,
                          "rid": best.rid,
                          "cost": round(best_c, 3)})
        if (moved == 0 and costs[d] > hi and self.migrate
                and donor.paged and recip.paged and recip.free_slots > 0
                and donor.free_slots == 0 and not donor.queue):
            cands = [s for s in donor.migratable_slots()
                     if recip.can_host(int(donor.lens[s]))]
            if len(cands) > 1:          # the donor keeps one running
                slot = min(cands,
                           key=lambda s: (-donor.request_cost(
                               donor.slots[s], False),
                               donor.slots[s].rid))
                rid = donor.slots[slot].rid
                c = donor.request_cost(donor.slots[slot], False)
                mode = recip.migrate_in(donor.migrate_out(slot))
                costs[d] -= c
                costs[r] += c
                self.migrations += 1
                self.migration_modes[mode] += 1
                self.diffusion_moves += 1
                self.decisions.append(("diffuse_live", d, r, rid, mode))
                if self.tracer.enabled:
                    self.tracer.instant(
                        "diffuse_live", pid=self._fabric_pid,
                        args={"donor": donor.replica_id,
                              "recipient": recip.replica_id,
                              "rid": rid, "mode": mode,
                              "cost": round(c, 3)})

    # ------------------------------------------------- failure detection
    def _responsive(self, i: int) -> bool:
        return self.faults is None or self.faults.responsive(i)

    def _observed_load(self, i: int) -> int:
        """The load-vector entry for replica i: its real load when it
        answers the gather, its last-known load while unresponsive (a
        wedged replica holding all remaining work must not read as 0 —
        that would fire spurious termination), and 0 once declared
        dead (its work has been re-admitted elsewhere)."""
        if not self._alive[i]:
            return 0
        if not self._responsive(i):
            return self._last_load[i]
        self._last_load[i] = self.engines[i].load
        return self._last_load[i]

    def _detect_failures(self) -> None:
        """Heartbeat bookkeeping riding the load gather: a replica that
        misses ``heartbeat_misses`` CONSECUTIVE gathers is declared
        dead. One answered gather resets the window, so a slow replica
        (responsive, little progress) is never declared dead and a hang
        shorter than the window is absorbed with no recovery."""
        if self.faults is None:
            return
        for i in range(len(self.engines)):
            if not self._alive[i]:
                continue
            if self.faults.responsive(i):
                self._misses[i] = 0
                continue
            self._misses[i] += 1
            if self._misses[i] >= self.heartbeat_misses:
                self._declare_dead(i)

    def _declare_dead(self, i: int) -> None:
        """Fence replica i forever and run loss recovery: re-wire the
        lifeline topology over the survivors, clear the dead replica's
        pending rows/columns, and re-admit its lost requests. The dead
        engine object is never touched again — a zombie that wakes up
        after declaration is ignored (it is not stepped, not gathered,
        and its requests already have a new single owner)."""
        self._alive[i] = False
        self._misses[i] = 0
        if not any(self._alive):
            raise RuntimeError("every replica has died")
        self.replicas_dead += 1
        self.metrics.counter("replicas_dead").inc()
        if self.tracer.enabled:
            self.tracer.instant(
                "replica_dead", pid=self._fabric_pid,
                args={"replica": self.engines[i].replica_id,
                      "superstep": self.supersteps,
                      "window": self.heartbeat_misses},
            )
        z = int(self._buddies.shape[1])
        self._buddies = jnp.asarray(
            rewire_lifelines(np.asarray(self._alive), z)
        )
        pend = np.asarray(self._pending).copy()
        pend[i, :] = False     # its remembered requests die with it
        pend[:, i] = False     # nobody waits on a dead buddy
        self._pending = jnp.asarray(pend)
        self._recover(i)

    def _recover(self, dead: int) -> None:
        """Re-admit every request lost with replica ``dead``. Lost = in
        the submission ledger, not finished, and not observed live on
        any survivor — computed WITHOUT reading the dead engine (its
        state is unreachable by assumption; steals and migrations mean
        its original placement says nothing about current ownership).

        Queued casualties re-enter a survivor's queue (plain submit);
        running casualties (``req.out`` non-empty) are reconstructed as
        recompute resumes via the migration landing path with
        ``kv=None`` — the prompt and the already-streamed tokens are all
        that is needed, so greedy outputs stay token-identical to a
        crash-free run."""
        if not any(self._alive):
            raise RuntimeError("replica fabric lost every replica")
        live_rids = set()
        for j, e in enumerate(self.engines):
            if not self._alive[j]:
                continue
            live_rids.update(r.rid for r in e.queue)
            live_rids.update(r.rid for r in e.slots if r is not None)
        lost = sorted(
            (r for rid, r in self._ledger.items()
             if not r.done and rid not in live_rids),
            key=lambda r: r.rid,
        )
        for req in lost:
            if req.out:
                self._readmit_running(req, dead)
            else:
                self._readmit_queued(req, dead)

    def _trace_readmit(self, req: Request, dead: int, mode: str,
                       to: int) -> None:
        if not self.tracer.enabled:
            return
        self.tracer.req_instant(
            req.rid, "readmitted", pid=self._fabric_pid,
            args={"from": self.engines[dead].replica_id, "mode": mode},
        )
        self.tracer.instant(
            "request_readmitted", pid=self._fabric_pid,
            args={"rid": req.rid, "mode": mode,
                  "from": self.engines[dead].replica_id,
                  "to": self.engines[to].replica_id},
        )

    def _readmit_queued(self, req: Request, dead: int) -> None:
        alive = [i for i in range(len(self.engines)) if self._alive[i]]
        to = alive[self._rr % len(alive)]
        self._trace_readmit(req, dead, "queued", to)
        self.submit(req)        # advances _rr, lands on `to`
        self.readmitted_queued += 1
        self.metrics.counter("requests_readmitted").inc()

    def _readmit_running(self, req: Request, dead: int) -> None:
        target = None
        for j, e in enumerate(self.engines):
            if not self._alive[j] or not e.paged:
                continue
            if e.can_host(len(e._prefix_tokens(req))):
                target = j
                break
        if target is None:
            # The non-survivable case (DESIGN.md §15): a running
            # sequence needs a paged survivor whose max_seq fits the
            # recompute prefix. Contiguous engines have no resume path.
            raise RuntimeError(
                f"request {req.rid} ({len(req.out)} tokens in) lost with "
                f"replica {dead}: no surviving paged replica can host "
                f"its recompute resume"
            )
        eng = self.engines[target]
        tokens = eng._prefix_tokens(req)
        self._trace_readmit(req, dead, "recompute", target)
        mig = Migration(req=req, tokens=tokens, written=len(tokens),
                        block_size=0, kv=None)
        eng.migrate_in(mig)     # kv=None -> recompute requeue, front
        self._ledger[req.rid] = req
        self.readmitted_running += 1
        self.metrics.counter("requests_readmitted").inc()

    def balance(self) -> bool:
        """One balancing pass. Returns True when the fabric is done —
        the load vector gathered for the steal matching doubles as the
        GLB termination detector, so callers need no separate poll (and,
        with a fault injector attached, the same gather is the
        heartbeat: see ``_detect_failures``)."""
        if self.faults is not None:
            self.faults.begin_superstep(self.supersteps)
        self._detect_failures()
        loads = np.asarray(
            [self._observed_load(i) for i in range(len(self.engines))],
            np.int32,
        )
        if self.slo is not None:
            self.slo.check()
        if self.tracer.enabled:
            # The GLB size vector as a counter track — the measurement a
            # cost-modeled balancer will regress on.
            self.tracer.counter(
                "fabric_load",
                {f"replica{i}": int(v) for i, v in enumerate(loads)},
                pid=self._fabric_pid,
            )
        if terminated(loads):
            self.terminated = True
            if self.tracer.enabled:
                self.tracer.instant("terminated", pid=self._fabric_pid,
                                    args={"superstep": self.supersteps})
            return True
        # Dead and unresponsive replicas neither give nor take: their
        # sizes are 0 and they are never hungry, so the matching routes
        # around them; pending edges toward them were cleared at death.
        active = [self._alive[i] and self._responsive(i)
                  for i in range(len(self.engines))]
        if self.predictive:
            # Diffusive pre-pass on predicted cost — proactive moves
            # first, the reactive matching below mops up anything the
            # predictions missed (including replicas the diffusion left
            # starving). Strictly additive: with predictive off nothing
            # here runs and the pass below is byte-identical to the
            # pre-cost balancer.
            self._diffuse(active)
        thieves = [e for i, e in enumerate(self.engines)
                   if active[i] and e.can_accept() and len(e.queue) == 0]
        sizes = np.asarray(
            [self._stealable(e, thieves) if active[i] else 0
             for i, e in enumerate(self.engines)],
            np.int32,
        )
        hungry = np.asarray(
            [active[i] and e.can_accept() and len(e.queue) == 0
             for i, e in enumerate(self.engines)]
        )
        m = match_steals(
            jnp.asarray(sizes), jnp.asarray(hungry), self._pending,
            jax.random.fold_in(jax.random.key(17), self._step),
            self._buddies, self.params,
        )
        self._pending = m.pending
        src = np.asarray(m.src)
        for thief, victim in enumerate(src):
            if victim < 0:
                continue
            v = self.engines[int(victim)]
            if v.queue:
                # Tier 1: steal queued (unstarted) requests first.
                take = max(1, len(v.queue) // 2)
                took = min(take, len(v.queue))
                if self.predictive:
                    # Cost-weighted selection: ship the most expensive
                    # queued requests (rid tie-break) so each steal
                    # moves the most predicted work. Predictive-only
                    # branch; the default path below is untouched.
                    ranked = sorted(
                        v.queue,
                        key=lambda q: (-v.request_cost(q, True), q.rid))
                    for q in ranked[:took]:
                        v.queue.remove(q)
                        self.engines[thief].submit(q)
                        self.queue_moves += 1
                else:
                    for _ in range(took):
                        # Oldest-first: stolen requests keep their
                        # arrival order on the thief, not the victim's
                        # inverted tail.
                        self.engines[thief].submit(v.queue.popleft())
                        self.queue_moves += 1
                self.decisions.append(("q", v.replica_id,
                                       self.engines[thief].replica_id,
                                       took))
                if self.tracer.enabled:
                    self.tracer.instant(
                        "steal_queued", pid=self._fabric_pid,
                        args={"victim": v.replica_id,
                              "thief": self.engines[thief].replica_id,
                              "n": took},
                    )
            elif self.migrate and v.paged and self.engines[thief].paged:
                self._steal_live(self.engines[thief], v)
        self._step += 1
        return False

    def run(self, max_steps: int = 10_000) -> str:
        """Drive the fabric to completion: balance, superstep every
        engine, repeat until the balance pass reports termination. Each
        iteration is a ``superstep`` span on the fabric track (a no-op
        context manager when tracing is off — per superstep, not per
        token).

        Returns ``"terminated"`` (GLB termination fired) or
        ``"wedged"`` (``max_steps`` exhausted with work outstanding —
        also emitted as a ``fabric_wedged`` trace instant, so a stuck
        fabric is distinguishable from a finished one without poking at
        internals). Dead replicas are fenced (never stepped); a faulted
        replica only steps when the injector says it makes progress."""
        while max_steps > 0:
            with self.tracer.span("superstep", pid=self._fabric_pid,
                                  args={"n": self.supersteps}):
                if self.balance():
                    break
                for i, e in enumerate(self.engines):
                    if not self._alive[i]:
                        continue
                    if self.faults is not None \
                            and not self.faults.should_step(i):
                        continue
                    e.step()
                self.supersteps += 1
            max_steps -= 1
        if self.terminated:
            return "terminated"
        if self.tracer.enabled:
            self.tracer.instant(
                "fabric_wedged", pid=self._fabric_pid,
                args={"supersteps": self.supersteps,
                      "loads": [int(self._observed_load(i))
                                for i in range(len(self.engines))]},
            )
        return "wedged"

    # ------------------------------------------------------ result collection
    def collect(self) -> dict:
        """Fabric-level result collection: merge per-replica stats into
        one report (total/mean/max per field) plus the balancer's own
        counters."""
        merged = merge_place_stats([e.stats() for e in self.engines])
        merged["_balancer"] = {
            "moves": self.moves,
            "queue_moves": self.queue_moves,
            "migrations": self.migrations,
            "diffusion_moves": self.diffusion_moves,
            "sterile_steals": self.sterile_steals,
            "supersteps": self.supersteps,
            "replicas_dead": self.replicas_dead,
            "readmitted_queued": self.readmitted_queued,
            "readmitted_running": self.readmitted_running,
            **{f"mig_{k}": v for k, v in self.migration_modes.items()},
        }
        if self.slo is not None:
            merged["_slo"] = self.slo.snapshot()
        if self.cost_model is not None:
            merged["_cost"] = self.cost_model.snapshot()
        return merged

    def merged_metrics(self) -> MetricsRegistry:
        """Fabric-level metrics registry: counters add, gauges keep the
        high-water mark, histograms merge bucket counts — so quantiles
        are of the MERGED latency distribution, not averages of
        per-replica quantiles. Feed to ``render_prometheus()`` for a
        fabric scrape."""
        for e in self.engines:
            e.stats()               # sync attr-backed gauges first
        return MetricsRegistry.merged(
            [e.metrics for e in self.engines] + [self.metrics]
        )

    def report(self) -> str:
        """Human-readable fabric summary (``core.stats.fabric_summary``
        over the merged registry view ``collect()`` produces) plus the
        balancer counters."""
        lines = [fabric_summary(self.collect(), title="replica fabric",
                                places=len(self.engines))]
        lines.append(
            f"  balancer: {self.moves} moves ({self.queue_moves} queued "
            f"+ {self.migrations} live migrations: "
            f"{self.migration_modes['live']} live / "
            f"{self.migration_modes['seeded']} seeded / "
            f"{self.migration_modes['recompute']} recompute), "
            f"{self.supersteps} supersteps, terminated={self.terminated}"
        )
        if self.predictive:
            cm = self.cost_model
            lines.append(
                f"  predictive: {self.diffusion_moves} diffusion moves "
                f"(threshold {self.imbalance_threshold:g}), "
                f"{len(cm.errors)} predictions scored, "
                f"mean |err| {cm.mean_abs_error():.1f} tokens"
            )
        if self.replicas_dead:
            lines.append(
                f"  failures: {self.replicas_dead} replica(s) dead, "
                f"{self.readmitted_queued + self.readmitted_running} "
                f"requests re-admitted ({self.readmitted_queued} queued "
                f"/ {self.readmitted_running} recompute)"
            )
        if self.slo is not None:
            lines += [f"  {ln}" for ln in self.slo.report_lines()]
        return "\n".join(lines)
