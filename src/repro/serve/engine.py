"""Serving engine: continuous batching over decode slots + GLB request
balancing across replicas.

Each replica owns a fixed pool of decode slots (static shapes). New
requests prefill into a free slot (prompts padded to a bucket length,
KV/conv state written into a reused preallocated row cache — no
``make_cache`` allocation churn per admission); all active slots advance
``steps_per_sync`` tokens per engine step inside ONE jitted
``lax.fori_loop`` decode: sampling (greedy or temperature, device-side
PRNG key threading) happens on device, per-slot done masks gate cache
writes and length/budget accounting, and each step emits an
(N, slots) token buffer the host drains with a single device->host sync —
~N× fewer host round-trips than the per-token loop (kept as
``step_legacy`` for benchmarking). Per-slot cache lengths (-1 marks an
idle slot: its cache/state is untouched) flow through to the split-KV
flash-decode kernel.

The multi-replica balancer treats per-replica queue depth as the GLB size
vector and moves queued requests from overloaded to idle replicas with the
same deterministic matching the task scheduler uses — the paper's library
applied to serving (DESIGN.md §4/§6).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import GLBParams, lifeline_buddies, match_steals
from repro.models import decode_step, forward, make_cache, sample_tokens
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


def _make_fns(cfg: ModelConfig, max_seq: int, pad_len: int,
              steps_per_sync: int, temperature: float):
    vocab = cfg.vocab

    def _scrub_row(row):
        # The reused row cache carries the previous request's state.
        # Attention k/v tails are harmless (masked by cache length), but
        # recurrent conv/ssm state feeds prefill directly and must be zero.
        return {
            name: (leaf if name in ("k", "v") else jnp.zeros_like(leaf))
            for name, leaf in row.items()
        }

    @jax.jit
    def prefill_into_slot(params, tokens, cache, slot, row, true_len, key):
        logits, row, _ = forward(
            params, cfg, tokens=tokens, cache=_scrub_row(row),
            cache_len=jnp.int32(0), mode="prefill",
        )
        def put(c, r):
            start = (0, slot) + (0,) * (c.ndim - 2)
            return jax.lax.dynamic_update_slice(c, r.astype(c.dtype), start)
        cache = jax.tree.map(put, cache, row)
        first = sample_tokens(
            logits[0, true_len - 1, ..., :vocab], key, temperature
        )
        return first, cache, row

    @jax.jit
    def decode_tokens(params, tokens, cache, lens, budget, key):
        """steps_per_sync decode steps entirely on device. Carries per-slot
        done masks (idle: lens < 0; finished: budget == 0) and fills an
        (N, slots) token buffer (-1 where a slot emitted nothing) that the
        host drains with one sync."""
        B = tokens.shape[0]
        buf = jnp.full((steps_per_sync, B), -1, jnp.int32)

        def body(t, carry):
            tokens, cache, lens, budget, key, buf = carry
            active = (lens >= 0) & (budget > 0)
            step_lens = jnp.where(active, lens, -1)
            logits, cache = decode_step(params, cfg, tokens, cache,
                                        step_lens)
            key, sub = jax.random.split(key)
            nxt = sample_tokens(logits[:, 0, ..., :vocab], sub, temperature)
            nxt = jnp.where(active, nxt, -1)
            buf = buf.at[t].set(nxt)
            lens = jnp.where(active, lens + 1, lens)
            budget = jnp.where(active, budget - 1, budget)
            budget = jnp.where(lens >= max_seq - 1, 0, budget)  # cache full
            tokens = jnp.where(active[:, None], nxt[:, None], tokens)
            return tokens, cache, lens, budget, key, buf

        carry = (tokens, cache, lens, budget, key, buf)
        tokens, cache, lens, budget, key, buf = jax.lax.fori_loop(
            0, steps_per_sync, body, carry
        )
        return buf, cache, key

    @jax.jit
    def decode_one(params, tokens, cache, lens):
        # Pre-fast-path decode: one step, greedy, logits -> host argmax is
        # the caller's job historically; argmax stays on device here but
        # the loop still syncs every token (step_legacy baseline).
        logits, cache = decode_step(params, cfg, tokens, cache, lens)
        nxt = jnp.argmax(logits[:, 0, ..., :vocab], axis=-1)
        return nxt.astype(jnp.int32), cache

    return prefill_into_slot, decode_tokens, decode_one


class Engine:
    def __init__(self, cfg: ModelConfig, params, max_slots: int = 4,
                 max_seq: int = 256, pad_len: int = 32,
                 steps_per_sync: int = 8, temperature: float = 0.0,
                 seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.max_seq = max_seq
        self.pad_len = pad_len
        self.steps_per_sync = steps_per_sync
        self.queue: deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * max_slots
        self.lens = np.full(max_slots, -1, np.int32)    # -1 => idle slot
        self.budget = np.zeros(max_slots, np.int32)     # tokens still owed
        self.cache = make_cache(cfg, max_slots, max_seq, dtype=jnp.float32)
        self._row = make_cache(cfg, 1, max_seq, dtype=jnp.float32)
        self.tokens = np.zeros((max_slots, 1), np.int32)
        self._key = jax.random.key(seed)
        self._prefill, self._decode_n, self._decode_1 = _make_fns(
            cfg, max_seq, pad_len, steps_per_sync, temperature
        )
        self.steps = 0
        self.tokens_out = 0
        self.host_syncs = 0    # blocking device->host transfer points

    def submit(self, req: Request):
        self.queue.append(req)

    @property
    def load(self) -> int:
        return len(self.queue) + sum(s is not None for s in self.slots)

    def _admit(self):
        for i in range(self.max_slots):
            if self.slots[i] is None and self.queue:
                req = self.queue.popleft()
                true_len = min(len(req.prompt), self.pad_len)
                toks = np.zeros((1, self.pad_len), np.int32)
                toks[0, :true_len] = req.prompt[:true_len]
                self._key, sub = jax.random.split(self._key)
                first, self.cache, self._row = self._prefill(
                    self.params, jnp.asarray(toks), self.cache, i,
                    self._row, true_len, sub,
                )
                first = int(first)          # one sync per admission
                self.host_syncs += 1
                req.out.append(first)
                self.slots[i] = req
                self.lens[i] = true_len
                self.budget[i] = req.max_new
                self.tokens[i, 0] = first
                self.tokens_out += 1

    def _finish_check(self, i: int, req: Request):
        if (len(req.out) > req.max_new
                or self.lens[i] >= self.max_seq - 1
                or self.budget[i] <= 0):
            req.done = True
            self.slots[i] = None
            self.lens[i] = -1
            self.budget[i] = 0

    def step(self):
        """One engine iteration: admit, then `steps_per_sync` batched
        decode steps on device with ONE host drain at the end (idle slots
        carry lens=-1 and stay untouched)."""
        self._admit()
        if all(s is None for s in self.slots):
            return
        buf, self.cache, self._key = self._decode_n(
            self.params, jnp.asarray(self.tokens), self.cache,
            jnp.asarray(self.lens), jnp.asarray(self.budget), self._key,
        )
        buf = np.asarray(buf)               # the single drain
        self.host_syncs += 1
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            toks = buf[:, i]
            toks = toks[toks >= 0]
            req.out.extend(int(t) for t in toks)
            n = len(toks)
            if n:
                self.tokens[i, 0] = toks[-1]
            self.lens[i] += n
            self.budget[i] -= n
            self.tokens_out += n
            self._finish_check(i, req)
        self.steps += 1

    def step_legacy(self):
        """The pre-fast-path loop: ONE decode step and one host round-trip
        per token. Kept as the bench_serve / equivalence baseline."""
        self._admit()
        if all(s is None for s in self.slots):
            return
        nxt, self.cache = self._decode_1(
            self.params, jnp.asarray(self.tokens), self.cache,
            jnp.asarray(self.lens),
        )
        nxt = np.asarray(nxt)
        self.host_syncs += 1
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            tok = int(nxt[i])
            req.out.append(tok)
            self.tokens[i, 0] = tok
            self.lens[i] += 1
            self.budget[i] -= 1
            self.tokens_out += 1
            self._finish_check(i, req)
        self.steps += 1


class GLBReplicaBalancer:
    """GLB over replicas: queue depths are the size vector; hungry replicas
    steal queued requests via the deterministic matching."""

    def __init__(self, engines: List[Engine],
                 params: GLBParams = GLBParams()):
        self.engines = engines
        self.params = params
        P = len(engines)
        z = params.resolve_z(P)
        self._buddies = jnp.asarray(lifeline_buddies(P, z))
        self._pending = jnp.zeros((P, P), bool)
        self._step = 0
        self.moves = 0

    def submit(self, req: Request, rr: Optional[int] = None):
        i = (req.rid if rr is None else rr) % len(self.engines)
        self.engines[i].submit(req)

    def balance(self):
        sizes = np.asarray([len(e.queue) for e in self.engines], np.int32)
        hungry = np.asarray([e.load == 0 for e in self.engines])
        m = match_steals(
            jnp.asarray(sizes), jnp.asarray(hungry), self._pending,
            jax.random.fold_in(jax.random.key(17), self._step),
            self._buddies, self.params,
        )
        self._pending = m.pending
        src = np.asarray(m.src)
        for thief, victim in enumerate(src):
            if victim < 0:
                continue
            v = self.engines[int(victim)]
            take = max(1, len(v.queue) // 2)
            for _ in range(min(take, len(v.queue))):
                self.engines[thief].submit(v.queue.pop())
                self.moves += 1
        self._step += 1

    def run(self, max_steps: int = 10_000):
        while any(e.load > 0 for e in self.engines) and max_steps > 0:
            self.balance()
            for e in self.engines:
                e.step()
            max_steps -= 1
