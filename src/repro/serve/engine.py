"""Serving engine: continuous batching over decode slots + GLB request
balancing across replicas.

Each replica owns a fixed pool of decode slots (static shapes). New
requests prefill into a free slot (prompts padded to a bucket length); all
active slots advance one token per engine step in a single batched decode
with per-slot cache lengths (-1 marks an idle slot: its cache/state is
untouched). The multi-replica balancer treats per-replica queue depth as
the GLB size vector and moves queued requests from overloaded to idle
replicas with the same deterministic matching the task scheduler uses —
the paper's library applied to serving (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import GLBParams, lifeline_buddies, match_steals
from repro.models import decode_step, forward, make_cache
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


def _make_fns(cfg: ModelConfig, max_seq: int, pad_len: int):
    @jax.jit
    def prefill_into_slot(params, tokens, cache, slot):
        row = make_cache(cfg, 1, max_seq, dtype=jnp.float32)
        logits, row, _ = forward(
            params, cfg, tokens=tokens, cache=row,
            cache_len=jnp.int32(0), mode="prefill",
        )
        def put(c, r):
            start = (0, slot) + (0,) * (c.ndim - 2)
            return jax.lax.dynamic_update_slice(c, r.astype(c.dtype), start)
        cache = jax.tree.map(put, cache, row)
        return logits[0, :, ..., : cfg.vocab], cache

    @jax.jit
    def decode(params, tokens, cache, lens):
        logits, cache = decode_step(params, cfg, tokens, cache, lens)
        nxt = jnp.argmax(logits[:, 0, ..., : cfg.vocab], axis=-1)
        return nxt.astype(jnp.int32), cache

    return prefill_into_slot, decode


class Engine:
    def __init__(self, cfg: ModelConfig, params, max_slots: int = 4,
                 max_seq: int = 256, pad_len: int = 32):
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.max_seq = max_seq
        self.pad_len = pad_len
        self.queue: deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * max_slots
        self.lens = np.full(max_slots, -1, np.int32)   # -1 => idle slot
        self.cache = make_cache(cfg, max_slots, max_seq, dtype=jnp.float32)
        self.tokens = np.zeros((max_slots, 1), np.int32)
        self._prefill, self._decode = _make_fns(cfg, max_seq, pad_len)
        self.steps = 0
        self.tokens_out = 0

    def submit(self, req: Request):
        self.queue.append(req)

    @property
    def load(self) -> int:
        return len(self.queue) + sum(s is not None for s in self.slots)

    def _admit(self):
        for i in range(self.max_slots):
            if self.slots[i] is None and self.queue:
                req = self.queue.popleft()
                true_len = min(len(req.prompt), self.pad_len)
                toks = np.zeros((1, self.pad_len), np.int32)
                toks[0, :true_len] = req.prompt[:true_len]
                logits, self.cache = self._prefill(
                    self.params, jnp.asarray(toks), self.cache, i
                )
                first = int(np.asarray(logits)[true_len - 1].argmax())
                req.out.append(first)
                self.slots[i] = req
                self.lens[i] = true_len
                self.tokens[i, 0] = first
                self.tokens_out += 1

    def step(self):
        """One engine iteration: admit, then ONE batched decode for all
        active slots (idle slots carry lens=-1 and stay untouched)."""
        self._admit()
        if all(s is None for s in self.slots):
            return
        nxt, self.cache = self._decode(
            self.params, jnp.asarray(self.tokens), self.cache,
            jnp.asarray(self.lens),
        )
        nxt = np.asarray(nxt)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            tok = int(nxt[i])
            req.out.append(tok)
            self.tokens[i, 0] = tok
            self.lens[i] += 1
            self.tokens_out += 1
            if (len(req.out) > req.max_new
                    or self.lens[i] >= self.max_seq - 1):
                req.done = True
                self.slots[i] = None
                self.lens[i] = -1
        self.steps += 1


class GLBReplicaBalancer:
    """GLB over replicas: queue depths are the size vector; hungry replicas
    steal queued requests via the deterministic matching."""

    def __init__(self, engines: List[Engine],
                 params: GLBParams = GLBParams()):
        self.engines = engines
        self.params = params
        P = len(engines)
        z = params.resolve_z(P)
        self._buddies = jnp.asarray(lifeline_buddies(P, z))
        self._pending = jnp.zeros((P, P), bool)
        self._step = 0
        self.moves = 0

    def submit(self, req: Request, rr: Optional[int] = None):
        i = (req.rid if rr is None else rr) % len(self.engines)
        self.engines[i].submit(req)

    def balance(self):
        sizes = np.asarray([len(e.queue) for e in self.engines], np.int32)
        hungry = np.asarray([e.load == 0 for e in self.engines])
        m = match_steals(
            jnp.asarray(sizes), jnp.asarray(hungry), self._pending,
            jax.random.fold_in(jax.random.key(17), self._step),
            self._buddies, self.params,
        )
        self._pending = m.pending
        src = np.asarray(m.src)
        for thief, victim in enumerate(src):
            if victim < 0:
                continue
            v = self.engines[int(victim)]
            take = max(1, len(v.queue) // 2)
            for _ in range(min(take, len(v.queue))):
                self.engines[thief].submit(v.queue.pop())
                self.moves += 1
        self._step += 1

    def run(self, max_steps: int = 10_000):
        while any(e.load > 0 for e in self.engines) and max_steps > 0:
            self.balance()
            for e in self.engines:
                e.step()
            max_steps -= 1
