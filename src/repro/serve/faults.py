"""Fault injection for the GLB fabric — one chaos harness, two workload
shapes (DESIGN.md §15).

The injector models the three failure shapes a distributed GLB deployment
actually sees, keyed to the superstep clock both schedulers already run on:

* **crash** — the place stops answering the load-vector gather and never
  comes back. Its queued/running work is lost and must be re-admitted by
  the survivors (the balancer's ledger recovery / the simulator's bag
  drain).
* **hang** — the place stops answering for ``duration`` supersteps, then
  resumes. A hang shorter than the detection window (``heartbeat_misses``
  consecutive missed gathers) is absorbed with no recovery; a longer one
  is indistinguishable from a crash at detection time, so the place is
  declared dead and **fenced**: even after it "wakes up" it is never
  stepped again (a zombie double-producing tokens would corrupt the
  fabric).
* **slow** — the place answers every gather (responsive) but only makes
  compute progress every ``factor``-th superstep. A slow place must NOT
  be declared dead — this is the shape that tests the detection window's
  specificity, not its sensitivity.

The same injector drives both the serving fabric (``GLBReplicaBalancer``
consults ``responsive``/``should_step`` per replica per balance pass) and
the taskbag simulator (``core.scheduler.run_sim(faults=...)`` consults it
per place per superstep). "Replica" and "place" are the same index space
to the injector.

Determinism: the injector holds no RNG — faults fire at the exact
superstep they were scheduled for, so chaos tests are seeded and
reproducible, and the crash-at-every-superstep sweep is a plain loop.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scheduled fault. ``kind`` in {"crash", "hang", "slow"}.

    at        — superstep index the fault fires (inclusive).
    duration  — hang only: supersteps until the place resumes
                (None = never, equivalent to crash).
    factor    — slow only: the place steps once every `factor`
                supersteps from `at` on.
    """

    kind: str
    place: int
    at: int
    duration: Optional[int] = None
    factor: int = 2

    def __post_init__(self):
        if self.kind not in ("crash", "hang", "slow"):
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.kind == "slow" and self.factor < 2:
            raise ValueError("slow fault needs factor >= 2")

    def _active(self, step: int) -> bool:
        if step < self.at:
            return False
        if self.kind == "hang" and self.duration is not None:
            return step < self.at + self.duration
        return True


class FaultInjector:
    """Schedule of faults consulted by the superstep loop.

    Protocol (both schedulers follow it):
      1. ``begin_superstep(step)`` once per superstep, before the gather;
      2. ``responsive(p)`` — does place p answer this gather? (heartbeat)
      3. ``should_step(p)`` — does place p make compute progress this
         superstep? (a crashed/hung place doesn't; a slow one sometimes)
    """

    def __init__(self, faults: Optional[List[Fault]] = None):
        self.faults: List[Fault] = list(faults or [])
        self._step = 0
        self.fired: List[Fault] = []   # faults that have activated at
                                       # least once (for reports/tests)

    # ------------------------------------------------------------ schedule
    def crash(self, place: int, at: int) -> "FaultInjector":
        self.faults.append(Fault("crash", place, at))
        return self

    def hang(self, place: int, at: int,
             duration: Optional[int] = None) -> "FaultInjector":
        self.faults.append(Fault("hang", place, at, duration=duration))
        return self

    def slow(self, place: int, at: int, factor: int = 2) -> "FaultInjector":
        self.faults.append(Fault("slow", place, at, factor=factor))
        return self

    # ------------------------------------------------------------- queries
    def begin_superstep(self, step: int) -> None:
        self._step = step
        for f in self.faults:
            if f._active(step) and f not in self.fired:
                self.fired.append(f)

    def responsive(self, place: int) -> bool:
        """Heartbeat: does `place` answer this superstep's load gather?
        Slow places always do — slowness is a compute property, not a
        liveness one."""
        for f in self.faults:
            if f.place == place and f.kind in ("crash", "hang") \
                    and f._active(self._step):
                return False
        return True

    def should_step(self, place: int) -> bool:
        """Does `place` make compute progress this superstep?"""
        for f in self.faults:
            if f.place != place or not f._active(self._step):
                continue
            if f.kind in ("crash", "hang"):
                return False
            if f.kind == "slow" and (self._step - f.at) % f.factor != 0:
                return False
        return True
