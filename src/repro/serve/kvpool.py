"""Block-granular KV-cache pool: the host-side allocator behind paged
serving (vLLM's PagedAttention memory model applied to this stack).

The device holds one flat pool of KV blocks per attention layer
(``models.make_paged_cache``); this module owns the *mapping* — which
physical block backs logical block ``i`` of sequence ``s``. Key
properties:

* **free-list allocator** — a min-heap of free physical block ids, so
  allocation order is deterministic (lowest id first) and test-stable
  regardless of free order;
* **refcounted blocks** — ``fork`` shares a parent's blocks with the
  child by bumping refcounts, so a shared prompt prefix occupies HBM
  once no matter how many continuations hang off it;
* **copy-on-write** — ``reserve``/``extend`` return ``(src, dst)``
  physical copy pairs for any shared block the sequence is about to
  write into (the partial tail block after a fork); the caller applies
  them to the device pool before decoding. Blocks a sequence only
  *reads* stay shared forever;
* **reservation vs written** — ``reserve`` grows capacity (the
  scheduler's decode lookahead), ``advance`` records tokens actually
  written, ``extend`` does both; stats separate the two so
  fragmentation reports real waste, not lookahead;
* **stats** — occupancy (live blocks / pool size) and internal
  fragmentation (allocated-but-unused token slots) feed the serving
  scheduler's admission watermark and the GLB replica balancer's
  memory-pressure signal.

The pool never touches device memory: it hands out integer block ids and
copy instructions; ``serve.engine`` owns the jitted gather/scatter that
realizes them.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Tuple


class PoolExhausted(RuntimeError):
    """Raised when an alloc/extend needs more free blocks than exist."""


@dataclasses.dataclass(frozen=True)
class PoolStats:
    num_blocks: int
    block_size: int
    live_blocks: int          # blocks with refcount > 0
    free_blocks: int
    num_seqs: int
    used_tokens: int          # sum of per-seq WRITTEN lengths
    occupancy: float          # live_blocks / num_blocks
    fragmentation: float      # 1 - used / sum(per-seq allocated capacity):
                              # reserved-but-unwritten token slots (partial
                              # tail blocks + lookahead reservations).
                              # Per-seq denominator so forked shared blocks
                              # weigh once per owner, like the numerator.


class KVPool:
    """Host-side block allocator for the paged KV cache.

    ``num_blocks`` physical blocks of ``block_size`` tokens each. A
    sequence's logical address space is its block table: logical token
    ``t`` lives in physical block ``table[t // block_size]`` at offset
    ``t % block_size``.
    """

    def __init__(self, num_blocks: int, block_size: int):
        assert num_blocks > 0 and block_size > 0
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free: List[int] = list(range(num_blocks))
        heapq.heapify(self._free)
        self._ref = [0] * num_blocks
        self._tables: Dict[int, List[int]] = {}
        self._lens: Dict[int, int] = {}

    # ------------------------------------------------------------ internals
    def _take_block(self) -> int:
        if not self._free:
            raise PoolExhausted("KV pool out of blocks")
        b = heapq.heappop(self._free)
        assert self._ref[b] == 0
        self._ref[b] = 1
        return b

    def _drop_block(self, b: int) -> None:
        assert self._ref[b] > 0, f"double free of block {b}"
        self._ref[b] -= 1
        if self._ref[b] == 0:
            heapq.heappush(self._free, b)

    def _nblocks(self, tokens: int) -> int:
        return -(-tokens // self.block_size) if tokens > 0 else 0

    # ------------------------------------------------------------------ api
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    def blocks_for(self, tokens: int) -> int:
        """Physical blocks a ``tokens``-long sequence needs."""
        return self._nblocks(tokens)

    def can_alloc(self, tokens: int) -> bool:
        return self._nblocks(tokens) <= self.free_blocks

    def has_seq(self, sid: int) -> bool:
        return sid in self._tables

    def seq_len(self, sid: int) -> int:
        return self._lens[sid]

    def block_table(self, sid: int) -> List[int]:
        return list(self._tables[sid])

    def capacity(self, sid: int) -> int:
        """Allocated token capacity (blocks x block_size)."""
        return len(self._tables[sid]) * self.block_size

    def alloc(self, sid: int, tokens: int) -> List[int]:
        """Allocate a new sequence whose first ``tokens`` tokens are (about
        to be) written. Returns its block table. Raises PoolExhausted
        (allocating nothing) if the free list is short."""
        if sid in self._tables:
            raise ValueError(f"seq {sid} already allocated")
        need = self._nblocks(tokens)
        if need > self.free_blocks:
            raise PoolExhausted(
                f"need {need} blocks, {self.free_blocks} free"
            )
        self._tables[sid] = [self._take_block() for _ in range(need)]
        self._lens[sid] = tokens
        return self.block_table(sid)

    def blocks_needed(self, sid: int, tokens: int) -> int:
        """Free blocks a ``reserve(sid, tokens)`` would consume: new
        blocks past current capacity PLUS one per shared block in the
        write range (the COW copies). Watermark checks must use this, not
        raw capacity arithmetic."""
        table = self._tables[sid]
        written = self._lens[sid]
        if tokens <= written:
            return 0
        end_blk = self._nblocks(tokens)
        cow = sum(
            1 for idx in range(written // self.block_size,
                               min(end_blk, len(table)))
            if self._ref[table[idx]] > 1
        )
        return max(end_blk - len(table), 0) + cow

    def reserve(self, sid: int, tokens: int) -> Tuple[List[int],
                                                      List[Tuple[int, int]]]:
        """Ensure capacity for ``tokens`` total WITHOUT advancing the
        written length (the scheduler's lookahead reservation). Returns
        ``(new_blocks, copies)`` where ``copies`` is a list of
        ``(src_phys, dst_phys)`` pairs the caller must apply to the device
        pool: a copy appears iff the next write position sits in a shared
        block (refcount > 1) — the copy-on-write step after ``fork``.
        Atomic: on PoolExhausted nothing changed."""
        table = self._tables[sid]
        written = self._lens[sid]
        if tokens <= written:
            return [], []
        end_blk = self._nblocks(tokens)
        need_new = max(end_blk - len(table), 0)
        # COW check: EVERY already-allocated shared block the write range
        # [written, tokens) touches — the partial tail block plus any
        # shared lookahead blocks a fork inherited. Blocks strictly before
        # the write range are read-only and stay shared.
        cow_idxs = [
            idx for idx in range(written // self.block_size,
                                 min(end_blk, len(table)))
            if self._ref[table[idx]] > 1
        ]
        if need_new + len(cow_idxs) > self.free_blocks:
            raise PoolExhausted(
                f"reserve needs {need_new + len(cow_idxs)} blocks, "
                f"{self.free_blocks} free"
            )
        copies: List[Tuple[int, int]] = []
        for idx in cow_idxs:
            src = table[idx]
            dst = self._take_block()
            copies.append((src, dst))
            self._drop_block(src)   # shared: stays alive for the other seq
            table[idx] = dst
        new_blocks = [self._take_block() for _ in range(need_new)]
        table.extend(new_blocks)
        return new_blocks, copies

    def advance(self, sid: int, tokens: int) -> None:
        """Record that the sequence's written length grew to ``tokens``
        (must stay within reserved capacity; never shrinks)."""
        if tokens > self.capacity(sid):
            raise ValueError(
                f"advance({tokens}) beyond capacity {self.capacity(sid)}"
            )
        self._lens[sid] = max(self._lens[sid], tokens)

    def extend(self, sid: int, tokens: int) -> Tuple[List[int],
                                                     List[Tuple[int, int]]]:
        """Grow seq ``sid`` to ``tokens`` *written* tokens: reserve the
        capacity (COW included) and advance in one call."""
        out = self.reserve(sid, tokens)
        if tokens > self._lens[sid]:
            self.advance(sid, tokens)
        return out

    def fork(self, parent: int, child: int) -> List[int]:
        """Register ``child`` sharing every block of ``parent`` (prefix
        cached once). Blocks become refcount-shared; the child's first
        write past the shared prefix triggers the COW copy in extend()."""
        if child in self._tables:
            raise ValueError(f"seq {child} already allocated")
        table = self._tables[parent]
        for b in table:
            self._ref[b] += 1
        self._tables[child] = list(table)
        self._lens[child] = self._lens[parent]
        return self.block_table(child)

    def free(self, sid: int) -> None:
        """Release the sequence: each block's refcount drops, blocks
        return to the free heap at refcount 0. Freeing an unknown sid
        raises (double-free guard)."""
        if sid not in self._tables:
            raise KeyError(f"seq {sid} not allocated (double free?)")
        for b in self._tables.pop(sid):
            self._drop_block(b)
        del self._lens[sid]

    # ---------------------------------------------------------------- stats
    def stats(self) -> PoolStats:
        live = self.num_blocks - self.free_blocks
        used = sum(self._lens.values())
        # Per-seq capacity: forked shared blocks count once per owner, the
        # same way the written numerator does, so the ratio stays in [0,1].
        cap = sum(len(t) for t in self._tables.values()) * self.block_size
        return PoolStats(
            num_blocks=self.num_blocks,
            block_size=self.block_size,
            live_blocks=live,
            free_blocks=self.free_blocks,
            num_seqs=len(self._tables),
            used_tokens=used,
            occupancy=live / self.num_blocks,
            fragmentation=max(0.0, 1.0 - used / cap) if cap else 0.0,
        )

    @property
    def occupancy(self) -> float:
        return (self.num_blocks - self.free_blocks) / self.num_blocks
