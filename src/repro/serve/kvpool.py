"""Block-granular KV-cache pool: the host-side allocator behind paged
serving (vLLM's PagedAttention memory model applied to this stack).

The device holds one flat pool of KV blocks per attention layer
(``models.make_paged_cache``); this module owns the *mapping* — which
physical block backs logical block ``i`` of sequence ``s``. Key
properties:

* **free-list allocator** — a min-heap of free physical block ids, so
  allocation order is deterministic (lowest id first) and test-stable
  regardless of free order;
* **refcounted blocks** — ``fork`` shares a parent's blocks with the
  child by bumping refcounts, so a shared prompt prefix occupies HBM
  once no matter how many continuations hang off it;
* **copy-on-write** — ``reserve``/``extend`` return ``(src, dst)``
  physical copy pairs for any shared block the sequence is about to
  write into (the partial tail block after a fork); the caller applies
  them to the device pool before decoding. Blocks a sequence only
  *reads* stay shared forever;
* **reservation vs written** — ``reserve`` grows capacity (the
  scheduler's decode lookahead), ``advance`` records tokens actually
  written, ``extend`` does both; stats separate the two so
  fragmentation reports real waste, not lookahead;
* **live migration** — ``extract`` names the physical blocks holding a
  sequence's written tokens (the dense transfer set for shipping a
  *running* sequence to another replica) and ``inject`` re-materializes
  a migrated sequence over fresh blocks on the receiving pool; both are
  id-level only — ``serve.engine`` owns the device gather/scatter;
* **reclaimable blocks** — the radix prefix cache (``serve.radix``)
  holds references on blocks whose only owner is the cache itself;
  those blocks are *reclaimable*: they count toward
  ``available_blocks`` (so a warm cache never blocks admission) and
  ``ensure_free`` evicts them on demand before an alloc/reserve gives
  up. Eviction and preemption therefore share one accounting — the
  scheduler's watermark math sees free + cached, and only when both
  run out does PoolExhausted trigger a preemption;
* **stats** — occupancy (live blocks / pool size) and internal
  fragmentation (allocated-but-unused token slots) feed the serving
  scheduler's admission watermark and the GLB replica balancer's
  memory-pressure signal.

The pool never touches device memory: it hands out integer block ids and
copy instructions; ``serve.engine`` owns the jitted gather/scatter that
realizes them.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Dict, List, Optional, Tuple


class PoolExhausted(RuntimeError):
    """Raised when an alloc/extend needs more free blocks than exist."""


@dataclasses.dataclass(frozen=True)
class PoolStats:
    """Point-in-time snapshot of the block pool's occupancy and
    fragmentation (returned by ``KVPool.stats()``)."""

    num_blocks: int
    block_size: int
    live_blocks: int          # blocks with refcount > 0
    free_blocks: int
    num_seqs: int
    used_tokens: int          # sum of per-seq WRITTEN lengths
    cached_blocks: int        # reclaimable: referenced ONLY by the
                              # prefix cache (free-on-demand)
    occupancy: float          # live_blocks / num_blocks
    fragmentation: float      # 1 - used / sum(per-seq allocated capacity):
                              # reserved-but-unwritten token slots (partial
                              # tail blocks + lookahead reservations).
                              # Per-seq denominator so forked shared blocks
                              # weigh once per owner, like the numerator.


class KVPool:
    """Host-side block allocator for the paged KV cache.

    ``num_blocks`` physical blocks of ``block_size`` tokens each. A
    sequence's logical address space is its block table: logical token
    ``t`` lives in physical block ``table[t // block_size]`` at offset
    ``t % block_size``.
    """

    def __init__(self, num_blocks: int, block_size: int):
        assert num_blocks > 0 and block_size > 0
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free: List[int] = list(range(num_blocks))
        heapq.heapify(self._free)
        self._ref = [0] * num_blocks
        self._tables: Dict[int, List[int]] = {}
        self._lens: Dict[int, int] = {}
        # Prefix-cache accounting: blocks the radix tree owns, and an
        # INCREMENTAL count of how many are reclaimable (refcount == 1,
        # i.e. only the tree references them). available_blocks sits on
        # the scheduler hot path, so this must never walk the tree.
        self._cache_owned: set = set()
        self._reclaimable = 0
        # Eviction hook, wired up by the radix prefix cache: reclaim(n)
        # evicts cache entries until ~n blocks return to the free heap.
        self._reclaim_fn: Optional[Callable[[int], int]] = None

    # ------------------------------------------------------------ internals
    def _take_block(self) -> int:
        if not self._free:
            raise PoolExhausted("KV pool out of blocks")
        b = heapq.heappop(self._free)
        assert self._ref[b] == 0
        assert b not in self._cache_owned
        self._ref[b] = 1
        return b

    def _drop_block(self, b: int) -> None:
        assert self._ref[b] > 0, f"double free of block {b}"
        self._ref[b] -= 1
        if self._ref[b] == 1 and b in self._cache_owned:
            self._reclaimable += 1      # last non-tree reference gone
        if self._ref[b] == 0:
            assert b not in self._cache_owned
            heapq.heappush(self._free, b)

    def _nblocks(self, tokens: int) -> int:
        return -(-tokens // self.block_size) if tokens > 0 else 0

    # ------------------------------------------------------------------ api
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def cached_blocks(self) -> int:
        """Blocks referenced only by the prefix cache (reclaimable).
        Maintained incrementally — O(1), safe on the scheduler hot path."""
        return self._reclaimable

    @property
    def available_blocks(self) -> int:
        """Free blocks plus cache-only blocks that eviction can return.
        All admission/watermark arithmetic uses this, so a warm prefix
        cache never costs capacity — eviction and preemption share one
        accounting."""
        return len(self._free) + self._reclaimable

    def attach_reclaimer(self, reclaim_fn: Callable[[int], int]) -> None:
        """Register the prefix cache's evict hook."""
        self._reclaim_fn = reclaim_fn

    def ensure_free(self, need: int) -> None:
        """Evict cached-but-unreferenced blocks until ``need`` are free (or
        the cache runs dry — the caller's exhaustion check then fires)."""
        if need > len(self._free) and self._reclaim_fn is not None:
            self._reclaim_fn(need - len(self._free))

    def refcount(self, b: int) -> int:
        return self._ref[b]

    def add_ref(self, b: int) -> None:
        """Take an extra sequence reference on a live block (fork/adopt).
        A cache-owned block gaining a sequence reference stops being
        reclaimable — eviction could no longer free it."""
        assert self._ref[b] > 0, f"add_ref on dead block {b}"
        if self._ref[b] == 1 and b in self._cache_owned:
            self._reclaimable -= 1
        self._ref[b] += 1

    def cache_ref(self, b: int) -> None:
        """The radix tree takes ownership of a live block (insert path).
        The inserting sequence still holds its reference, so the block
        becomes reclaimable only when that sequence frees."""
        assert self._ref[b] > 0, f"cache_ref on dead block {b}"
        assert b not in self._cache_owned, f"block {b} cached twice"
        self._cache_owned.add(b)
        self._ref[b] += 1

    def cache_unref(self, b: int) -> bool:
        """The radix tree drops ownership (eviction). Returns True when
        the block actually returned to the free heap."""
        assert b in self._cache_owned, f"evicting uncached block {b}"
        self._cache_owned.discard(b)
        if self._ref[b] == 1:
            self._reclaimable -= 1      # was counted as reclaimable
        self._drop_block(b)
        return self._ref[b] == 0

    def blocks_for(self, tokens: int) -> int:
        """Physical blocks a ``tokens``-long sequence needs."""
        return self._nblocks(tokens)

    def can_alloc(self, tokens: int) -> bool:
        return self._nblocks(tokens) <= self.available_blocks

    def has_seq(self, sid: int) -> bool:
        return sid in self._tables

    def seq_len(self, sid: int) -> int:
        return self._lens[sid]

    def block_table(self, sid: int) -> List[int]:
        return list(self._tables[sid])

    def capacity(self, sid: int) -> int:
        """Allocated token capacity (blocks x block_size)."""
        return len(self._tables[sid]) * self.block_size

    def alloc(self, sid: int, tokens: int) -> List[int]:
        """Allocate a new sequence whose first ``tokens`` tokens are (about
        to be) written. Returns its block table. Raises PoolExhausted
        (allocating nothing) if the free list is short."""
        if sid in self._tables:
            raise ValueError(f"seq {sid} already allocated")
        need = self._nblocks(tokens)
        if need > self.available_blocks:
            raise PoolExhausted(
                f"need {need} blocks, {self.available_blocks} available"
            )
        self.ensure_free(need)
        if need > self.free_blocks:    # cache eviction under-delivered
            raise PoolExhausted(
                f"need {need} blocks, {self.free_blocks} free after evict"
            )
        self._tables[sid] = [self._take_block() for _ in range(need)]
        self._lens[sid] = tokens
        return self.block_table(sid)

    def adopt(self, sid: int, blocks: List[int], tokens: int) -> List[int]:
        """Register a new sequence over already-live shared blocks (a
        prefix-cache hit): refcounts bump, nothing is allocated, and the
        first write into the shared partial tail COWs via reserve() like
        any forked sequence. ``blocks`` must cover exactly ``tokens``."""
        if sid in self._tables:
            raise ValueError(f"seq {sid} already allocated")
        assert self._nblocks(tokens) == len(blocks), (tokens, blocks)
        for b in blocks:
            self.add_ref(b)
        self._tables[sid] = list(blocks)
        self._lens[sid] = tokens
        return self.block_table(sid)

    def blocks_needed(self, sid: int, tokens: int) -> int:
        """Free blocks a ``reserve(sid, tokens)`` would consume: new
        blocks past current capacity PLUS one per shared block in the
        write range (the COW copies). Watermark checks must use this, not
        raw capacity arithmetic."""
        table = self._tables[sid]
        written = self._lens[sid]
        if tokens <= written:
            return 0
        end_blk = self._nblocks(tokens)
        cow = sum(
            1 for idx in range(written // self.block_size,
                               min(end_blk, len(table)))
            if self._ref[table[idx]] > 1
        )
        return max(end_blk - len(table), 0) + cow

    def reserve(self, sid: int, tokens: int) -> Tuple[List[int],
                                                      List[Tuple[int, int]]]:
        """Ensure capacity for ``tokens`` total WITHOUT advancing the
        written length (the scheduler's lookahead reservation). Returns
        ``(new_blocks, copies)`` where ``copies`` is a list of
        ``(src_phys, dst_phys)`` pairs the caller must apply to the device
        pool: a copy appears iff the next write position sits in a shared
        block (refcount > 1) — the copy-on-write step after ``fork``.
        Atomic: on PoolExhausted nothing changed."""
        table = self._tables[sid]
        written = self._lens[sid]
        if tokens <= written:
            return [], []
        end_blk = self._nblocks(tokens)
        need_new = max(end_blk - len(table), 0)
        # COW check: EVERY already-allocated shared block the write range
        # [written, tokens) touches — the partial tail block plus any
        # shared lookahead blocks a fork inherited. Blocks strictly before
        # the write range are read-only and stay shared.
        cow_idxs = [
            idx for idx in range(written // self.block_size,
                                 min(end_blk, len(table)))
            if self._ref[table[idx]] > 1
        ]
        need = need_new + len(cow_idxs)
        if need > self.available_blocks:
            raise PoolExhausted(
                f"reserve needs {need} blocks, "
                f"{self.available_blocks} available"
            )
        self.ensure_free(need)
        if need > self.free_blocks:    # cache eviction under-delivered
            raise PoolExhausted(
                f"reserve needs {need} blocks, "
                f"{self.free_blocks} free after evict"
            )
        copies: List[Tuple[int, int]] = []
        for idx in cow_idxs:
            src = table[idx]
            dst = self._take_block()
            copies.append((src, dst))
            self._drop_block(src)   # shared: stays alive for the other seq
            table[idx] = dst
        new_blocks = [self._take_block() for _ in range(need_new)]
        table.extend(new_blocks)
        return new_blocks, copies

    def advance(self, sid: int, tokens: int) -> None:
        """Record that the sequence's written length grew to ``tokens``
        (must stay within reserved capacity; never shrinks)."""
        if tokens > self.capacity(sid):
            raise ValueError(
                f"advance({tokens}) beyond capacity {self.capacity(sid)}"
            )
        self._lens[sid] = max(self._lens[sid], tokens)

    def extend(self, sid: int, tokens: int) -> Tuple[List[int],
                                                     List[Tuple[int, int]]]:
        """Grow seq ``sid`` to ``tokens`` *written* tokens: reserve the
        capacity (COW included) and advance in one call."""
        out = self.reserve(sid, tokens)
        if tokens > self._lens[sid]:
            self.advance(sid, tokens)
        return out

    def extract(self, sid: int) -> Tuple[List[int], int]:
        """Pack descriptor for live migration (DESIGN.md §9): the physical
        blocks holding the sequence's WRITTEN tokens — full blocks plus
        the partial tail — and the written length. Lookahead-only blocks
        (reserved, never written) are excluded: the thief re-reserves its
        own lookahead. The pool stays untouched; the engine gathers these
        blocks into a dense device buffer and calls ``free`` once the
        transfer is out the door."""
        written = self._lens[sid]
        return self._tables[sid][: self._nblocks(written)], written

    def inject(self, sid: int, tokens: int) -> List[int]:
        """Re-materialize a migrated-in sequence: allocate fresh blocks
        covering ``tokens`` written tokens and register the sequence over
        them (the ``extract`` counterpart on the thief). Atomic — raises
        PoolExhausted (after cache eviction) without allocating anything
        when the pool cannot fit the sequence; the caller then falls back
        to resume-by-recompute."""
        return self.alloc(sid, tokens)

    def fork(self, parent: int, child: int) -> List[int]:
        """Register ``child`` sharing every block of ``parent`` (prefix
        cached once). Blocks become refcount-shared; the child's first
        write past the shared prefix triggers the COW copy in extend()."""
        if child in self._tables:
            raise ValueError(f"seq {child} already allocated")
        table = self._tables[parent]
        for b in table:
            self.add_ref(b)
        self._tables[child] = list(table)
        self._lens[child] = self._lens[parent]
        return self.block_table(child)

    def free(self, sid: int) -> None:
        """Release the sequence: each block's refcount drops, blocks
        return to the free heap at refcount 0. Freeing an unknown sid
        raises (double-free guard)."""
        if sid not in self._tables:
            raise KeyError(f"seq {sid} not allocated (double free?)")
        for b in self._tables.pop(sid):
            self._drop_block(b)
        del self._lens[sid]

    # ---------------------------------------------------------------- stats
    def stats(self) -> PoolStats:
        live = self.num_blocks - self.free_blocks
        used = sum(self._lens.values())
        # Per-seq capacity: forked shared blocks count once per owner, the
        # same way the written numerator does, so the ratio stays in [0,1].
        cap = sum(len(t) for t in self._tables.values()) * self.block_size
        return PoolStats(
            num_blocks=self.num_blocks,
            block_size=self.block_size,
            live_blocks=live,
            free_blocks=self.free_blocks,
            num_seqs=len(self._tables),
            used_tokens=used,
            cached_blocks=self.cached_blocks,
            occupancy=live / self.num_blocks,
            fragmentation=max(0.0, 1.0 - used / cap) if cap else 0.0,
        )

    @property
    def occupancy(self) -> float:
        return (self.num_blocks - self.free_blocks) / self.num_blocks
