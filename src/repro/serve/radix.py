"""Radix prefix cache: automatic shared-prefix KV reuse over the block
pool (SGLang's RadixAttention memory model on top of serve/kvpool.py).

Millions of requests sharing a system prompt re-prefill identical KV
blocks; this module makes the shared prefix a *cache hit* instead. A
radix tree over token sequences maps prefixes to the refcounted physical
blocks that already hold their KV:

* **node boundaries are block-aligned** — every edge label is a whole
  number of pool blocks and owns exactly the blocks its token span
  covers, so each cached block has exactly one owning node and eviction
  of a node is eviction of a block range;
* **matching is token-granular** — a lookup may end mid-block (the new
  prompt diverges inside a cached block, or simply ends there). The hit
  forks the covering blocks into the new sequence via ``KVPool.adopt``;
  the shared partial tail block is COW'd by the scheduler's ordinary
  ``reserve`` call on first write, so PR 3's fork/COW mechanism is the
  entire safety story — the cache adds policy, not new aliasing rules;
* **insert on release** — when a sequence finishes, its written tokens'
  full blocks are threaded into the tree and the tree takes a reference
  on each newly-cached block. ``KVPool.free`` then drops the sequence's
  references and the cached blocks survive at refcount 1, owned only by
  the tree: *reclaimable*;
* **LRU leaf eviction** — reclaimable blocks count toward the pool's
  ``available_blocks`` and are freed on demand (``KVPool.ensure_free``
  calls back into ``evict``): fully-reclaimable leaves go first,
  least-recently-used, cascading upward as parents become leaves; a
  leaf partially pinned by a live fork is sacrificed only when nothing
  cleaner remains, and its pinned blocks stay alive for their sequences
  (refcounts, not the tree, keep KV safe) — eviction can never pull KV
  out from under a decode.

A full-prefix hit is capped at ``len(tokens) - 1`` reused tokens so the
admission still computes at least one position — the logits that sample
the first output token.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.obs import NULL_TRACER

from .kvpool import KVPool


def _lcp(a, b) -> int:
    n = min(len(a), len(b))
    i = 0
    while i < n and a[i] == b[i]:
        i += 1
    return i


class _Node:
    """One radix-tree edge+node: ``tokens`` is the edge label from
    ``parent`` (a multiple of block_size long, except the root's empty
    label) and ``blocks`` are the physical pool blocks backing exactly
    those tokens. Children are keyed by the first block's token tuple —
    unique because siblings diverge inside their first block."""

    __slots__ = ("tokens", "blocks", "children", "parent", "last_use")

    def __init__(self, tokens: List[int], blocks: List[int],
                 parent: Optional["_Node"]):
        self.tokens = tokens
        self.blocks = blocks
        self.children: Dict[Tuple[int, ...], "_Node"] = {}
        self.parent = parent
        self.last_use = 0

    def key(self, bs: int) -> Tuple[int, ...]:
        return tuple(self.tokens[:bs])


class RadixPrefixCache:
    """Block-aligned radix tree over token sequences mapping shared
    prefixes to the refcounted pool blocks that already hold their KV —
    lookups via :meth:`probe`/:meth:`fork`, population via
    :meth:`insert` on sequence release, reclamation via LRU leaf
    :meth:`evict` (see module docstring for the full invariants)."""

    def __init__(self, pool: KVPool, tracer=None, pid: int = 0):
        self.pool = pool
        self.bs = pool.block_size
        # Observability: hit/evict instants on the owning replica's track.
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.pid = pid
        self.root = _Node([], [], None)
        self._clock = 0                # logical LRU clock (deterministic)
        self.hits = 0
        self.misses = 0
        self.tokens_reused = 0         # prefill positions never recomputed
        self.evictions = 0             # leaf nodes dropped
        self.cached_tokens = 0         # tokens currently in the tree
        self.seeded_tokens = 0         # tokens planted by live migration
        pool.attach_reclaimer(self.evict)

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    # ------------------------------------------------------------- lookup
    def _walk(self, tokens, cap: int):
        """Longest cached prefix of ``tokens[:cap]``. Returns
        (matched_len, covering_blocks, path_nodes); the last path node may
        be only partially matched."""
        node, p = self.root, 0
        blocks: List[int] = []
        path: List[_Node] = []
        while p < cap:
            rem = tokens[p:]
            child = None
            if len(rem) >= self.bs:
                child = node.children.get(tuple(rem[: self.bs]))
            if child is None:
                # token-granular partial match inside some child's first
                # block (deterministic: longest lcp, key-order tie-break)
                best, best_m = None, 0
                for k in sorted(node.children):
                    m = _lcp(node.children[k].tokens, rem)
                    if m > best_m:
                        best, best_m = node.children[k], m
                if best_m == 0:
                    break
                m = min(best_m, cap - p)
                blocks += best.blocks[: -(-m // self.bs)]
                path.append(best)
                p += m
                break
            m = min(_lcp(child.tokens, rem), cap - p)
            blocks += child.blocks[: -(-m // self.bs)]
            path.append(child)
            p += m
            if m < len(child.tokens):
                break
            node = child
        return p, blocks, path

    def probe(self, tokens) -> Tuple[int, List[int], List[_Node]]:
        """Read-only walk (the scheduler's admission predicate): longest
        cached prefix capped at len(tokens)-1, the blocks covering it,
        and the matched path. No refcounts move and the LRU clock is
        untouched; pass the result to fork() to commit without a second
        walk."""
        cap = len(tokens) - 1
        if cap <= 0:
            return 0, [], []
        return self._walk(tokens, cap)

    def match(self, tokens) -> Tuple[int, List[int]]:
        p, blocks, _ = self.probe(tokens)
        return p, blocks

    def hit_length(self, tokens) -> int:
        """Read-only cached-prefix length for ``tokens`` (the cost
        model's cache-credit input, DESIGN.md §16): how many prefill
        positions this replica would serve from cached blocks if the
        request were admitted right now. Same walk and same
        ``len(tokens)-1`` cap as :meth:`probe`; no refcounts move and
        the LRU clock is untouched, so pricing a request on every
        balance pass cannot perturb eviction order."""
        p, _, _ = self.probe(tokens)
        return p

    def fork(self, sid: int, tokens, probe=None) -> int:
        """Commit a hit: adopt the matched blocks into sequence ``sid``
        (refcount bump, zero recompute for the covered tokens) and
        refresh the LRU clock along the path. ``probe`` reuses a walk
        probe() already did — the tree cannot have changed in between.
        Returns the matched length; 0 counts as a miss."""
        p, blocks, path = probe if probe is not None else self.probe(tokens)
        if p == 0:
            self.misses += 1
            return 0
        self.pool.adopt(sid, blocks, p)
        now = self._tick()
        for nd in path:
            nd.last_use = now
        self.hits += 1
        self.tokens_reused += p
        if self.tracer.enabled:
            self.tracer.instant("cache_hit", pid=self.pid,
                                args={"sid": sid, "matched": p,
                                      "blocks": len(blocks)})
        return p

    # ------------------------------------------------------------- insert
    def _split(self, node: _Node, at: int) -> None:
        """Split ``node``'s edge at block-aligned token offset ``at``:
        the label tail (and its blocks and children) moves into a new
        child of ``node``."""
        assert 0 < at < len(node.tokens) and at % self.bs == 0
        tail = _Node(node.tokens[at:], node.blocks[at // self.bs:], node)
        tail.children = node.children
        for c in tail.children.values():
            c.parent = tail
        tail.last_use = node.last_use
        node.tokens = node.tokens[:at]
        node.blocks = node.blocks[: at // self.bs]
        node.children = {tail.key(self.bs): tail}

    def insert(self, tokens, table: List[int], written: int) -> int:
        """Thread a finished sequence's full-block prefix into the tree.
        ``tokens``/``table`` are the sequence's written tokens and block
        table; only whole blocks are cached (the partial tail dies with
        the sequence). The tree takes a reference on each block of every
        NEW suffix edge; blocks whose content the tree already caches are
        left alone (the caller's ``free`` recycles the duplicates).
        Returns the number of newly-cached blocks. Call BEFORE
        ``pool.free(sid)``."""
        L = (min(written, len(tokens)) // self.bs) * self.bs
        if L <= 0:
            return 0
        now = self._tick()
        node, d = self.root, 0
        new_blocks = 0
        while d < L:
            node.last_use = now
            rem = tokens[d:L]
            child = node.children.get(tuple(rem[: self.bs]))
            if child is None:
                blocks = table[d // self.bs: L // self.bs]
                for b in blocks:
                    self.pool.cache_ref(b)
                leaf = _Node(list(rem), list(blocks), node)
                leaf.last_use = now
                node.children[leaf.key(self.bs)] = leaf
                new_blocks += len(blocks)
                self.cached_tokens += len(rem)
                break
            # whole-block-aligned common prefix with the existing edge
            m = (_lcp(child.tokens, rem) // self.bs) * self.bs
            assert m >= self.bs          # first block matched via the key
            if m < len(child.tokens):
                self._split(child, m)
            node = child
            d += m
        return new_blocks

    def seed(self, tokens, table: List[int], written: int) -> int:
        """Plant a migrated prefix (DESIGN.md §9): when a live migration
        cannot re-materialize a full sequence on this pool, the engine
        injects however many full blocks DO fit under a temporary seq id,
        scatters their KV, and seeds them here — the subsequent
        resume-by-recompute admission then *hits* the planted prefix and
        recomputes only the suffix. Same contract as ``insert`` (call
        before freeing the temporary seq); returns newly-cached blocks."""
        new = self.insert(tokens, table, written)
        self.seeded_tokens += new * self.bs   # only NEWLY-cached blocks:
        return new                            # dedup against the tree
                                              # must not inflate the stat

    # ----------------------------------------------------------- eviction
    def reclaimable_blocks(self) -> int:
        """Full-tree audit of what eviction could free right now. The
        pool tracks the same quantity incrementally (``cached_blocks``,
        O(1)); this O(tree) walk exists for tests/debugging — the
        property suite asserts the two always agree."""
        total = 0
        stack = [self.root]
        while stack:
            nd = stack.pop()
            total += sum(1 for b in nd.blocks if self.pool.refcount(b) == 1)
            stack.extend(nd.children.values())
        return total

    def _evictable(self, nd: _Node) -> bool:
        return (nd.parent is not None and not nd.children and any(
            self.pool.refcount(b) == 1 for b in nd.blocks
        ))

    def _evictable_leaves(self) -> List[_Node]:
        out, stack = [], [self.root]
        while stack:
            nd = stack.pop()
            if self._evictable(nd):
                out.append(nd)
            stack.extend(nd.children.values())
        return out

    def evict(self, need: int) -> int:
        """Drop leaf ranges until ``need`` blocks returned to the free
        heap (or nothing evictable remains). Victim order: leaves whose
        blocks are ALL reclaimable first (pure wins), then LRU, so a leaf
        partially pinned by a live fork is sacrificed only when nothing
        cleaner remains — evicting it frees just its unpinned blocks;
        the pinned ones stay alive for their sequences (a running decode
        is never invalidated) but leave the cache when those sequences
        do. Cascades: a parent whose last child is dropped becomes an
        evictable candidate. One tree walk per call — refcounts of other
        candidates can't change mid-evict, so only the victim's parent
        needs (re)examining."""
        freed = 0
        cand = self._evictable_leaves()

        def rank(nd: _Node):
            pure = all(self.pool.refcount(b) == 1 for b in nd.blocks)
            return (0 if pure else 1, nd.last_use, nd.key(self.bs))

        while freed < need and cand:
            victim = min(cand, key=rank)
            cand.remove(victim)
            for b in victim.blocks:
                if self.pool.cache_unref(b):
                    freed += 1
            parent = victim.parent
            del parent.children[victim.key(self.bs)]
            self.cached_tokens -= len(victim.tokens)
            self.evictions += 1
            if self.tracer.enabled:
                self.tracer.instant("cache_evict", pid=self.pid,
                                    args={"tokens": len(victim.tokens),
                                          "freed_so_far": freed,
                                          "need": need})
            if self._evictable(parent):
                cand.append(parent)
        return freed

    # -------------------------------------------------------------- stats
    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0
