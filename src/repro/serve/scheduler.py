"""Continuous-batching scheduler: admission, lookahead block reservation,
prefix-cache-aware admission, chunked prefill, and preempt-and-requeue
over the paged KV pool.

This is the serving analogue of the GLB runtime loop the paper argues for
(§1-2): the *runtime*, not the request stream, decides what occupies the
accelerator each superstep. Per engine step the scheduler produces a
``StepPlan``:

* **token budget** — one pool of ``token_budget`` positions per step,
  shared by decode and prefill. Occupied slots are visited oldest-first:
  a mid-prefill slot claims its next chunk (at most ``prefill_chunk``
  tokens), a decoding slot claims up to ``lookahead`` positions
  (``plan.quota``); when the pool runs dry the rest pause this step. A
  long admission therefore costs at most the budget per step instead of
  stalling every co-scheduled decode for one giant prefill;
* **prefix cache** — on admission the radix cache (``serve.radix``) is
  probed for the longest cached prefix of the request's tokens; a hit
  forks the covering blocks into the new sequence (``KVPool.adopt``) and
  prefill starts at the matched offset — zero recompute for the hit, COW
  on the shared partial tail via the ordinary ``reserve`` path. All
  free-block arithmetic uses ``pool.available_blocks`` (free + cache-only
  blocks), so cached prefixes are evicted on demand rather than ever
  blocking admission — eviction and preemption share one accounting;
* **lookahead reservation** — every *active* sequence gets pool capacity
  for the full ``lookahead`` (= steps_per_sync) tokens the jitted decode
  loop will write, so the loop never runs out of blocks mid-flight. COW
  copies surfaced by ``KVPool.reserve`` are returned for the engine to
  apply before decoding;
* **watermark preemption** — when a reservation (or admission) would
  leave fewer than ``watermark_blocks`` available, the *youngest* running
  sequence is preempted: its blocks are freed and the request goes back
  to the FRONT of the queue with its generated tokens kept. Re-admission
  recomputes the cache by prefilling prompt + generated-so-far (resume by
  recompute — and, when the prompt's blocks survived in the prefix
  cache, the recompute is itself a hit). A sequence never preempts
  *itself*: with no younger victim it takes a partial reservation (the
  engine clamps that step's writes to the granted capacity), and the
  oldest sequence may consume the watermark headroom outright — so
  progress is guaranteed and a too-tight watermark degrades throughput,
  never liveness;
* **admission** — while a slot is free, the head of the queue fits under
  the watermark, and the token budget has room, requests are admitted
  strictly FIFO (head-of-line blocking preserves arrival order rather
  than back-filling around a big request). In chunked mode (prefix cache
  or ``prefill_chunk`` set) an admission enters the plan's ``prefill``
  list and decodes only after its last chunk lands; otherwise it takes
  the legacy single-shot ``admit`` path.

The scheduler owns every ``KVPool`` mutation; the engine owns the device
side (prefill scatter, COW block copies, chunk forwards, the decode
loop).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.obs import NULL_TRACER, MetricsRegistry, now_us

from .kvpool import KVPool, PoolExhausted

_INF = 1 << 30


@dataclasses.dataclass
class StepPlan:
    """One engine step's worth of scheduling decisions: who prefills
    (single-shot or chunked), who was preempted, which COW copies the
    engine must apply, and each slot's decode mask/reservation/quota.
    Produced by :meth:`ContinuousBatchingScheduler.plan_step`; the
    engine applies the device-side effects."""

    admit: List[Tuple[int, object]]          # (slot, request) single-shot
                                             # prefill (legacy path)
    prefill: List[Tuple[int, object, int, int, bool]]
                                             # (slot, req, start, end, last)
                                             # chunk of tokens [start, end)
                                             # to prefill this step
    preempted: List[Tuple[int, object]]      # (slot, request) freed+requeued
    copies: List[Tuple[int, int]]            # COW (src, dst) block copies
    active: np.ndarray                       # (slots,) bool decode mask
    granted: np.ndarray                      # (slots,) i32 token capacity
                                             # reserved per slot; the engine
                                             # clamps each slot's step budget
                                             # to granted - lens so a partial
                                             # reservation can never be
                                             # overrun by the decode loop
    quota: np.ndarray                        # (slots,) i32 decode positions
                                             # this slot may emit this step
                                             # (its slice of token_budget)


class ContinuousBatchingScheduler:
    """Plans one engine step over a shared KVPool. ``lookahead`` is how
    many tokens the jitted decode loop writes per step (steps_per_sync);
    ``watermark_blocks`` is the available-block floor that triggers
    preemption instead of reservation; ``token_budget`` caps positions
    (decode + prefill-chunk) scheduled per step (None = unlimited);
    ``prefill_chunk`` caps one sequence's prefill tokens per step;
    ``cache`` is the radix prefix cache (None = no prefix reuse)."""

    def __init__(self, pool: KVPool, max_slots: int, lookahead: int,
                 max_seq: int, watermark_blocks: int = 0,
                 token_budget: Optional[int] = None,
                 prefill_chunk: Optional[int] = None,
                 cache=None, shed_policy: str = "youngest",
                 tracer=None, metrics=None, slo=None,
                 slo_admission: bool = False, cost_model=None,
                 pid: int = 0):
        assert shed_policy in ("youngest", "budget"), shed_policy
        # SLO-aware admission (DESIGN.md §16): order the queue by TTFT
        # slack and pace non-urgent admissions. Off by default — the
        # default path must stay strictly FIFO, byte-identical to a
        # scheduler built without the flag.
        if slo_admission:
            if slo is None:
                raise ValueError("slo_admission requires an SLOMonitor")
            tgt = slo.target_ms("ttft_ms")
            if tgt is None:
                tgt = slo.target_ms("queue_wait_ms")
            if tgt is None:
                raise ValueError(
                    "slo_admission needs a ttft_ms or queue_wait_ms "
                    "target on the SLOMonitor")
            self._slo_target_ms = tgt
        self.slo_admission = slo_admission
        self.cost_model = cost_model
        self.paced_deferrals = 0               # admissions delayed by pacing
        # Observability: the engine hands down its tracer/registry so
        # admission/preemption events land on the owning replica's track
        # (pid) and queue-wait is observed where the commit happens.
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.slo = slo
        self.pid = pid
        self.pool = pool
        self.max_slots = max_slots
        self.lookahead = lookahead
        self.max_seq = max_seq
        self.watermark = watermark_blocks
        self.token_budget = token_budget
        self.prefill_chunk = prefill_chunk
        self.cache = cache
        self.shed_policy = shed_policy
        self._admit_seq = 0                    # monotonic admission clock
        self._order = [-1] * max_slots         # slot -> admission seqno
        self._prefill: Dict[int, List[int]] = {}   # slot -> [done, total]
        self.preemptions = 0
        self.admissions = 0
        self.chunks_scheduled = 0
        self.adoptions = 0                     # migrated-in sequences

    # --------------------------------------------------------------- helpers
    @property
    def chunked_mode(self) -> bool:
        """Admissions prefill via StepPlan.prefill chunks (budget-charged,
        decode-interleaved) instead of the legacy single-shot path."""
        return self.cache is not None or self.prefill_chunk is not None

    def mid_prefill(self, slot: int) -> bool:
        """True while ``slot`` still owes prefill chunks (it must not
        decode, and the engine's finish checks must skip it)."""
        return slot in self._prefill

    def _occupied_oldest_first(self, slots) -> List[int]:
        occ = [i for i in range(self.max_slots) if slots[i] is not None]
        return sorted(occ, key=lambda i: self._order[i])

    def _youngest(self, slots) -> Optional[int]:
        occ = [i for i in range(self.max_slots) if slots[i] is not None]
        if not occ:
            return None
        return max(occ, key=lambda i: self._order[i])

    def shed_candidates(self, slots: List, budgets) -> List[int]:
        """Live sequences the replica balancer may migrate out, best
        victim first (DESIGN.md §9). Mid-prefill slots are excluded —
        their KV is half-written and a migrated chunk plan would dangle.
        Policies: ``youngest`` (least cache invested: the cheapest
        transfer, and the mirror of preemption's victim order) or
        ``budget`` (largest remaining token budget: the move that
        offloads the most future work per transferred byte)."""
        occ = [i for i in range(self.max_slots)
               if slots[i] is not None and i not in self._prefill]
        if self.shed_policy == "budget":
            return sorted(occ, key=lambda i: (-int(budgets[i]),
                                              -self._order[i]))
        return sorted(occ, key=lambda i: -self._order[i])

    def adopt(self, slot: int) -> None:
        """Register a migrated-in sequence as a running slot WITHOUT
        passing through admission: the engine has already injected its
        blocks and host state. It takes the youngest admission seqno —
        it is the newest arrival here, so watermark preemption and a
        subsequent shed pass both see it as the natural first victim."""
        self._order[slot] = self._admit_seq
        self._admit_seq += 1
        self.adoptions += 1

    def _admission_slack_ms(self, req, prefix_len: int,
                            now_ref: float) -> float:
        """TTFT budget left for a queued request: declared target minus
        time already queued minus the cost model's predicted prefill
        service time (0 without a model). Negative = the target is
        already blown; smallest slack = most urgent."""
        waited = ((now_ref - req.t_queued) / 1e3
                  if getattr(req, "t_queued", 0.0) else 0.0)
        predicted = (self.cost_model.prefill_ms(prefix_len)
                     if self.cost_model is not None else 0.0)
        return self._slo_target_ms - waited - predicted

    def can_admit(self, prefix_len: int, engine_empty: bool) -> bool:
        """The balancer's hunger signal (``Engine.can_accept``): does a
        ``prefix_len`` admission plus decode lookahead fit, leaving the
        watermark headroom available — or, on an empty engine, fit at
        all? Counts cache-only blocks as available (they evict on
        demand) but assumes no prefix hit, so it is CONSERVATIVE
        relative to plan_step's own admission check, which additionally
        credits matched cache blocks (and un-credits the ones the fork
        would pin). A replica may therefore report not-hungry for a
        request plan_step would admit via a hit — safe in that
        direction; keep the two checks reviewed together."""
        target = min(prefix_len + self.lookahead, self.max_seq)
        need = self.pool.blocks_for(target)
        floor = 0 if engine_empty else self.watermark
        avail = self.pool.available_blocks
        return need <= avail and avail - need >= floor

    def _preempt(self, victim: int, slots, queue: Deque,
                 plan: StepPlan) -> None:
        req = slots[victim]
        self.pool.free(req.rid)
        plan.preempted.append((victim, req))
        # A half-prefilled victim restarts from scratch on re-admission
        # (its written blocks are gone); drop any chunk already planned
        # for it this step — the engine must not prefill a freed seq.
        self._prefill.pop(victim, None)
        plan.prefill = [e for e in plan.prefill if e[0] != victim]
        req.t_queued = now_us()
        if self.tracer.enabled:
            self.tracer.instant("preempt", pid=self.pid,
                                args={"slot": victim, "rid": req.rid})
            self.tracer.req_instant(req.rid, "preempted", pid=self.pid,
                                    args={"slot": victim})
            self.tracer.req_phase(req.rid, "queued", pid=self.pid)
        queue.appendleft(req)
        slots[victim] = None
        self._order[victim] = -1
        self.preemptions += 1

    def _plan_chunk(self, plan: StepPlan, slot: int, req,
                    budget_left: int) -> int:
        """Schedule the next prefill chunk for ``slot``; returns tokens
        charged against the step budget (0 = budget dry, no chunk)."""
        done, total = self._prefill[slot]
        # A zero-token prefill could never take its "last chunk" and
        # would wedge the slot mid-prefill forever; the engine rejects
        # empty prompts at submit, so this is unreachable — keep it loud.
        assert done < total, (slot, done, total)
        chunk = min(total - done, self.prefill_chunk or _INF, budget_left)
        if chunk <= 0:
            return 0
        end = done + chunk
        last = end >= total
        plan.prefill.append((slot, req, done, end, last))
        if last:
            del self._prefill[slot]
        else:
            self._prefill[slot][0] = end
        self.chunks_scheduled += 1
        return chunk

    # ------------------------------------------------------------------ plan
    def plan_step(self, queue: Deque, slots: List, lens: np.ndarray,
                  prefix_tokens_of) -> StepPlan:
        """Mutates ``queue``/``slots`` for preemptions and admissions
        (the engine applies the device-side effects afterwards).
        ``prefix_tokens_of(req)`` gives the token sequence an admission
        must have in cache before decoding (prompt, plus generated tokens
        when resuming) — the prefix-cache lookup key and the chunked
        prefill work list.

        Liveness: the oldest running sequence reserves below the
        watermark, shrinking to a partial reservation when no *younger*
        victim exists (it never preempts itself), and an empty engine
        admits the queue head on raw available blocks — so some sequence
        always makes progress and a too-tight watermark degrades to
        smaller steps instead of deadlock."""
        plan = StepPlan(admit=[], prefill=[], preempted=[], copies=[],
                        active=np.zeros(self.max_slots, bool),
                        granted=np.zeros(self.max_slots, np.int32),
                        quota=np.zeros(self.max_slots, np.int32))
        budget_left = (self.token_budget if self.token_budget is not None
                       else _INF)
        bs = self.pool.block_size

        # 1) oldest-first over occupied slots: mid-prefill slots claim
        #    their next chunk, decoding slots reserve lookahead capacity
        #    (preempting youngest-first at the watermark).
        for rank, i in enumerate(self._occupied_oldest_first(slots)):
            if slots[i] is None:
                continue                        # preempted above
            req = slots[i]
            if i in self._prefill:
                budget_left -= self._plan_chunk(plan, i, req, budget_left)
                continue                        # no decode while prefilling
            if budget_left <= 0:
                plan.granted[i] = min(self.pool.capacity(req.rid),
                                      self.max_seq)
                continue                        # paused: over token budget
            target = min(int(lens[i]) + self.lookahead, self.max_seq)
            # The oldest sequence may dip into the watermark headroom —
            # that headroom exists to protect *its* growth.
            floor = 0 if rank == 0 else self.watermark
            ok = False
            while True:
                try:
                    # blocks_needed counts COW copies too, so the floor
                    # check can't be sidestepped by a forked tail block.
                    need = self.pool.blocks_needed(req.rid, target)
                    if need > 0 and (self.pool.available_blocks - need
                                     < floor):
                        raise PoolExhausted("watermark")
                    _, copies = self.pool.reserve(req.rid, target)
                    plan.copies.extend(copies)
                    ok = True
                    break
                except PoolExhausted:
                    victim = self._youngest(slots)
                    if victim is not None and victim != i:
                        self._preempt(victim, slots, queue, plan)
                        continue
                    # No younger victim: shrink to what fits instead of
                    # preempting ourselves (which could never help).
                    usable = max(self.pool.available_blocks - floor, 0)
                    cur = len(self.pool.block_table(req.rid))
                    shrunk = min(target, (cur + usable) * bs)
                    if shrunk >= target:
                        break   # can't shrink further (e.g. COW starved)
                    target = shrunk
            granted = min(self.pool.capacity(req.rid), self.max_seq)
            plan.granted[i] = granted
            if ok and granted > int(lens[i]):
                plan.active[i] = True
                plan.quota[i] = min(self.lookahead, budget_left)
                budget_left -= int(plan.quota[i])

        # 2) FIFO admission while slots, blocks, and token budget allow.
        free_slots = deque(i for i in range(self.max_slots)
                           if slots[i] is None)
        # SLO-aware mode replaces arrival order with slack order (most
        # urgent first, rid tie-break — stable and deterministic) and
        # paces the relaxed tail: once one non-urgent request (slack >
        # half the target) has been admitted this step while work is
        # already running, further non-urgent admissions wait a step so
        # running decodes keep their token-budget share. Urgent requests
        # are never paced. Everything here is behind the flag: with
        # slo_admission off this block is dead code and admission stays
        # strictly FIFO.
        relaxed_admitted = 0
        now_ref = now_us() if self.slo_admission else 0.0
        if self.slo_admission and len(queue) > 1:
            ordered = sorted(
                queue,
                key=lambda r: (self._admission_slack_ms(
                    r, len(prefix_tokens_of(r)), now_ref), r.rid))
            queue.clear()
            queue.extend(ordered)
        while queue and free_slots and budget_left > 0:
            req = queue[0]
            ptoks = prefix_tokens_of(req)
            prefix = len(ptoks)
            if self.slo_admission:
                slack = self._admission_slack_ms(req, prefix, now_ref)
                relaxed = slack > 0.5 * self._slo_target_ms
                if (relaxed and relaxed_admitted >= 1
                        and any(s is not None for s in slots)):
                    self.paced_deferrals += 1
                    if self.tracer.enabled:
                        self.tracer.instant(
                            "admission_paced", pid=self.pid,
                            args={"rid": req.rid,
                                  "slack_ms": round(slack, 3)})
                    break
            target = min(prefix + self.lookahead, self.max_seq)
            floor = (0 if all(s is None for s in slots)
                     else self.watermark)
            avail = self.pool.available_blocks
            # Probe the prefix cache: a hit needs that many fewer fresh
            # blocks (plus one COW for a partially-matched tail block) —
            # but the matched blocks that are currently cache-only stop
            # being reclaimable the moment the fork pins them, so they
            # must come OUT of the available headroom too (counting them
            # on both sides would admit, fail in reserve, and retry the
            # queue head forever). When the hit-credited admission does
            # NOT fit, fall back to a plain miss admission — evicting the
            # prefix is better than never admitting the queue head.
            probe = (self.cache.probe(ptoks) if self.cache
                     else (0, [], []))
            matched, mblocks = probe[0], probe[1]
            pinned = sum(1 for b in mblocks
                         if self.pool.refcount(b) == 1)
            need_hit = (self.pool.blocks_for(target) - len(mblocks)
                        + (1 if matched % bs else 0))
            use_cache = (
                matched > 0
                and need_hit <= avail - pinned
                and (avail - pinned) - need_hit >= floor
            )
            need_miss = self.pool.blocks_for(target)
            # An idle engine admits on raw available blocks (progress
            # beats headroom when nothing is running to free any).
            if not use_cache and (need_miss > avail
                                  or avail - need_miss < floor):
                break                           # head-of-line: stay FIFO
            slot = free_slots[0]
            forked = 0
            try:
                if use_cache:
                    forked = self.cache.fork(req.rid, ptoks, probe=probe)
                elif self.cache is not None:
                    self.cache.misses += 1      # hit skipped or no match
                matched = forked
                if matched == 0:
                    # chunked mode starts at written=0 and prefills via
                    # chunks; the legacy path writes the whole prefix in
                    # its admission step.
                    self.pool.alloc(req.rid,
                                    0 if self.chunked_mode else prefix)
                _, copies = self.pool.reserve(req.rid, target)
            except PoolExhausted:
                # Cache eviction under-delivered (reclaimable blocks
                # pinned by live forks): undo the half-admission — blocks
                # AND hit/miss stats — and leave the head queued.
                if self.pool.has_seq(req.rid):
                    self.pool.free(req.rid)
                if self.cache is not None:
                    if forked:
                        self.cache.hits -= 1
                        self.cache.tokens_reused -= forked
                    else:
                        self.cache.misses -= 1
                break
            plan.copies.extend(copies)
            queue.popleft()
            free_slots.popleft()
            slots[slot] = req
            self._order[slot] = self._admit_seq
            self._admit_seq += 1
            self.admissions += 1
            if self.slo_admission and relaxed:
                relaxed_admitted += 1
            # Admission commit: the request leaves the queue here, for
            # both the chunked and legacy paths — the one site where
            # queue wait ends and the prefill phase begins.
            t_adm = now_us()
            if getattr(req, "t_queued", 0.0):
                wait_ms = (t_adm - req.t_queued) / 1e3
                self.metrics.histogram("queue_wait_ms").observe(wait_ms)
                if self.slo is not None:
                    self.slo.observe("queue_wait_ms", wait_ms)
            if self.tracer.enabled:
                self.tracer.req_phase(req.rid, "prefill", pid=self.pid,
                                      args={"slot": slot,
                                            "cached": matched})
            plan.granted[slot] = min(self.pool.capacity(req.rid),
                                     self.max_seq)
            if self.chunked_mode:
                self._prefill[slot] = [matched, prefix]
                budget_left -= self._plan_chunk(plan, slot, req,
                                                budget_left)
            else:
                plan.admit.append((slot, req))
                plan.active[slot] = True
                plan.quota[slot] = min(self.lookahead, budget_left)
                budget_left -= int(plan.quota[slot])
        if self.tracer.enabled:
            # The step's token-budget split: decode positions granted vs
            # prefill-chunk tokens scheduled — the per-step timeline a
            # cost-modeled balancer will read.
            self.tracer.counter(
                "token_budget",
                {"decode": float(plan.quota.sum()),
                 "prefill": float(sum(e[3] - e[2] for e in plan.prefill))},
                pid=self.pid,
            )
        return plan

    def release(self, rid: int) -> None:
        """A sequence finished: return its blocks to the pool (the engine
        threads its prefix into the radix cache first, so cached blocks
        survive the free at refcount 1)."""
        self.pool.free(rid)

    def slot_released(self, slot: int) -> None:
        self._order[slot] = -1
        self._prefill.pop(slot, None)
