"""Continuous-batching scheduler: admission, lookahead block reservation,
and preempt-and-requeue over the paged KV pool.

This is the serving analogue of the GLB runtime loop the paper argues for
(§1-2): the *runtime*, not the request stream, decides what occupies the
accelerator each superstep. Per engine step the scheduler produces a
``StepPlan``:

* **token budget** — the oldest running sequences are selected until
  ``token_budget`` decode positions (slots x steps_per_sync) are claimed;
  the rest pause this step (their slot state is untouched — a paused slot
  just passes lens = -1 into the decode loop);
* **lookahead reservation** — every *active* sequence gets pool capacity
  for the full ``lookahead`` (= steps_per_sync) tokens the jitted decode
  loop will write, so the loop never runs out of blocks mid-flight. COW
  copies surfaced by ``KVPool.extend`` are returned for the engine to
  apply before decoding;
* **watermark preemption** — when a reservation (or admission) would
  leave fewer than ``watermark_blocks`` free, the *youngest* running
  sequence is preempted: its blocks are freed and the request goes back
  to the FRONT of the queue with its generated tokens kept. Re-admission
  recomputes the cache by prefilling prompt + generated-so-far (resume by
  recompute), which keeps greedy decoding token-identical across a
  preempt/resume cycle. A sequence never preempts *itself*: with no
  younger victim it takes a partial reservation (the engine clamps that
  step's writes to the granted capacity), and the oldest sequence may
  consume the watermark headroom outright — so progress is guaranteed
  and a too-tight watermark degrades throughput, never liveness;
* **admission** — while a slot is free, the head of the queue fits under
  the watermark, and the token budget has room, requests are admitted
  strictly FIFO (head-of-line blocking preserves arrival order rather
  than back-filling around a big request).

The scheduler owns every ``KVPool`` mutation; the engine owns the device
side (prefill scatter, COW block copies, the decode loop).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional, Tuple

import numpy as np

from .kvpool import KVPool, PoolExhausted


@dataclasses.dataclass
class StepPlan:
    admit: List[Tuple[int, object]]          # (slot, request) to prefill
    preempted: List[Tuple[int, object]]      # (slot, request) freed+requeued
    copies: List[Tuple[int, int]]            # COW (src, dst) block copies
    active: np.ndarray                       # (slots,) bool decode mask
    granted: np.ndarray                      # (slots,) i32 token capacity
                                             # reserved per slot; the engine
                                             # clamps each slot's step budget
                                             # to granted - lens so a partial
                                             # reservation can never be
                                             # overrun by the decode loop


class ContinuousBatchingScheduler:
    """Plans one engine step over a shared KVPool. ``lookahead`` is how
    many tokens the jitted decode loop writes per step (steps_per_sync);
    ``watermark_blocks`` is the free-block floor that triggers preemption
    instead of reservation; ``token_budget`` caps decode positions
    scheduled per step (None = unlimited)."""

    def __init__(self, pool: KVPool, max_slots: int, lookahead: int,
                 max_seq: int, watermark_blocks: int = 0,
                 token_budget: Optional[int] = None):
        self.pool = pool
        self.max_slots = max_slots
        self.lookahead = lookahead
        self.max_seq = max_seq
        self.watermark = watermark_blocks
        self.token_budget = token_budget
        self._admit_seq = 0                    # monotonic admission clock
        self._order = [-1] * max_slots         # slot -> admission seqno
        self.preemptions = 0
        self.admissions = 0

    # --------------------------------------------------------------- helpers
    def _occupied_oldest_first(self, slots) -> List[int]:
        occ = [i for i in range(self.max_slots) if slots[i] is not None]
        return sorted(occ, key=lambda i: self._order[i])

    def _youngest(self, slots) -> Optional[int]:
        occ = [i for i in range(self.max_slots) if slots[i] is not None]
        if not occ:
            return None
        return max(occ, key=lambda i: self._order[i])

    def _max_active(self) -> int:
        if self.token_budget is None:
            return self.max_slots
        return max(1, self.token_budget // max(self.lookahead, 1))

    def can_admit(self, prefix_len: int, engine_empty: bool) -> bool:
        """THE admission predicate (plan_step and the balancer's hunger
        signal both use it, so they cannot drift): does a ``prefix_len``
        admission plus decode lookahead fit, leaving the watermark
        headroom free — or, on an empty engine, fit at all?"""
        target = min(prefix_len + self.lookahead, self.max_seq)
        need = self.pool.blocks_for(target)
        floor = 0 if engine_empty else self.watermark
        return (need <= self.pool.free_blocks
                and self.pool.free_blocks - need >= floor)

    def _preempt(self, victim: int, slots, queue: Deque,
                 plan: StepPlan) -> None:
        req = slots[victim]
        self.pool.free(req.rid)
        plan.preempted.append((victim, req))
        queue.appendleft(req)
        slots[victim] = None
        self._order[victim] = -1
        self.preemptions += 1

    # ------------------------------------------------------------------ plan
    def plan_step(self, queue: Deque, slots: List, lens: np.ndarray,
                  prefix_len_of) -> StepPlan:
        """Mutates ``queue``/``slots`` for preemptions and admissions
        (the engine applies the device-side effects afterwards).
        ``prefix_len_of(req)`` gives the cache rows an admission must
        prefill (prompt, plus generated tokens when resuming).

        Liveness: the oldest running sequence reserves below the
        watermark, shrinking to a partial reservation when no *younger*
        victim exists (it never preempts itself), and an empty engine
        admits the queue head on raw free blocks — so some sequence
        always makes progress and a too-tight watermark degrades to
        smaller steps instead of deadlock."""
        plan = StepPlan(admit=[], preempted=[], copies=[],
                        active=np.zeros(self.max_slots, bool),
                        granted=np.zeros(self.max_slots, np.int32))
        max_active = self._max_active()
        bs = self.pool.block_size

        # 1) reserve decode capacity for the oldest running sequences,
        #    preempting youngest-first at the watermark.
        n_active = 0
        for rank, i in enumerate(self._occupied_oldest_first(slots)):
            if slots[i] is None:
                continue                        # preempted above
            if n_active >= max_active:
                continue                        # paused: over token budget
            req = slots[i]
            target = min(int(lens[i]) + self.lookahead, self.max_seq)
            # The oldest sequence may dip into the watermark headroom —
            # that headroom exists to protect *its* growth.
            floor = 0 if rank == 0 else self.watermark
            ok = False
            while True:
                try:
                    # blocks_needed counts COW copies too, so the floor
                    # check can't be sidestepped by a forked tail block.
                    need = self.pool.blocks_needed(req.rid, target)
                    if need > 0 and (self.pool.free_blocks - need < floor):
                        raise PoolExhausted("watermark")
                    _, copies = self.pool.reserve(req.rid, target)
                    plan.copies.extend(copies)
                    ok = True
                    break
                except PoolExhausted:
                    victim = self._youngest(slots)
                    if victim is not None and victim != i:
                        self._preempt(victim, slots, queue, plan)
                        continue
                    # No younger victim: shrink to what fits instead of
                    # preempting ourselves (which could never help).
                    usable = max(self.pool.free_blocks - floor, 0)
                    cur = len(self.pool.block_table(req.rid))
                    shrunk = min(target, (cur + usable) * bs)
                    if shrunk >= target:
                        break   # can't shrink further (e.g. COW starved)
                    target = shrunk
            granted = min(self.pool.capacity(req.rid), self.max_seq)
            plan.granted[i] = granted
            if ok and granted > int(lens[i]):
                plan.active[i] = True
                n_active += 1

        # 2) FIFO admission while slots, blocks, and token budget allow.
        free_slots = deque(i for i in range(self.max_slots)
                           if slots[i] is None)
        while queue and free_slots and n_active < max_active:
            req = queue[0]
            prefix = prefix_len_of(req)
            target = min(prefix + self.lookahead, self.max_seq)
            # An idle engine admits on raw free blocks (progress beats
            # headroom when nothing is running to free any).
            if not self.can_admit(prefix, all(s is None for s in slots)):
                break                           # head-of-line: stay FIFO
            queue.popleft()
            slot = free_slots.popleft()
            self.pool.alloc(req.rid, prefix)
            self.pool.reserve(req.rid, target)
            slots[slot] = req
            self._order[slot] = self._admit_seq
            self._admit_seq += 1
            self.admissions += 1
            plan.admit.append((slot, req))
            plan.granted[slot] = min(self.pool.capacity(req.rid),
                                     self.max_seq)
            plan.active[slot] = True
            n_active += 1
        return plan

    def release(self, rid: int) -> None:
        """A sequence finished: return its blocks to the pool."""
        self.pool.free(rid)

    def slot_released(self, slot: int) -> None:
        self._order[slot] = -1
