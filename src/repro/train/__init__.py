"""Training substrate: hand-rolled AdamW + schedules, step builders."""
