"""AdamW + cosine schedule + global-norm clipping, hand-rolled (no optax in
the image). Optimizer state mirrors the param pytree so it inherits the
params' sharding (fully-sharded ZeRO-style states come for free under pjit).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def cosine_lr(step, oc: OptConfig):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(oc.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - oc.warmup_steps) / max(oc.total_steps - oc.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = oc.min_lr_frac + (1 - oc.min_lr_frac) * cos
    return oc.lr * warm * frac


def adamw_init(params) -> Dict[str, Any]:
    zeros = lambda: jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return {"m": zeros(), "v": zeros(), "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree.leaves(tree))
    )


def adamw_update(
    params, grads, opt, oc: OptConfig
) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    step = opt["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, oc.clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    lr = cosine_lr(step, oc)
    b1, b2 = oc.b1, oc.b2
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, opt["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, opt["v"], grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m_, v_):
        u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + oc.eps)
        u = u + oc.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "step": step}, {
        "grad_norm": gnorm, "lr": lr,
    }
