"""Step builders: train_step / prefill_step / decode_step factories.

These are the functions the launcher jits with shardings and the dry-run
lowers at 512 devices.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models import decode_step as _decode, prefill as _prefill, train_loss
from repro.models.config import ModelConfig

from .optimizer import OptConfig, adamw_init, adamw_update


def make_train_step(cfg: ModelConfig, oc: OptConfig, microbatches: int = 1):
    """Training step with optional gradient accumulation. The microbatch
    loop is UNROLLED (microbatches is small) so activation residency drops
    ~microbatches-x while XLA cost analysis still counts every pass —
    see EXPERIMENTS §Perf."""

    def grad_of(params, b):
        return jax.value_and_grad(
            lambda p: train_loss(p, cfg, b), has_aux=True
        )(params)

    def train_step(params, opt, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_of(params, batch)
        else:
            mbs = jax.tree.map(
                lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                    + x.shape[1:]),
                batch,
            )
            grads = None
            metrics = None
            for i in range(microbatches):
                b = jax.tree.map(lambda x: x[i], mbs)
                (_, m), g = grad_of(params, b)
                g = jax.tree.map(lambda a: a.astype(jnp.float32), g)
                grads = g if grads is None else jax.tree.map(
                    jnp.add, grads, g)
                metrics = m if metrics is None else jax.tree.map(
                    jnp.add, metrics, m)
            grads = jax.tree.map(lambda a: a / microbatches, grads)
            metrics = {
                k: (v if k == "expert_counts" else v / microbatches)
                for k, v in metrics.items()
            }
        params, opt, om = adamw_update(params, grads, opt, oc)
        metrics = dict(metrics, **om)
        return params, opt, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, max_seq: int):
    def prefill_step(params, batch):
        logits, cache = _prefill(params, cfg, batch, max_seq=max_seq)
        # serving returns only the last-position logits (greedy head here;
        # sampling lives in serve/engine.py)
        next_tok = jnp.argmax(logits[:, -1:, ..., : cfg.vocab], axis=-1)
        return next_tok, cache

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_one(params, tokens, cache, cache_len):
        logits, cache = _decode(params, cfg, tokens, cache, cache_len)
        next_tok = jnp.argmax(logits[..., : cfg.vocab], axis=-1)
        return next_tok, cache

    return decode_one


def init_train_state(key, cfg: ModelConfig):
    from repro.models import init_lm

    params = init_lm(key, cfg)
    return params, adamw_init(params)
