"""Auto-loaded when an interpreter starts with ``src`` on PYTHONPATH (the
``site`` module imports ``sitecustomize`` at startup). Installs the jax
backward-compat shims (see repro/_jaxcompat.py) before any user code runs,
so scripts that touch ``jax.sharding.AxisType`` / ``jax.shard_map`` prior
to importing repro — e.g. the subprocess bodies of the multi-device tests —
work on the image's jax 0.4.37.

Python only imports the *first* sitecustomize on sys.path, so after
installing the shims this module chain-loads any sitecustomize it shadowed
further down the path, preserving whatever the environment would have run
without this file.
"""
import importlib.util
import os
import sys

_SELF = os.path.realpath(__file__)

try:
    import repro._jaxcompat  # noqa: F401
except ImportError:
    # jax (or repro) not importable in this interpreter: nothing to shim.
    # Anything else raising is a real breakage and should surface.
    pass

for _entry in sys.path:
    _cand = os.path.join(_entry or ".", "sitecustomize.py")
    # realpath comparison: a symlinked second spelling of this directory on
    # sys.path must not make this file exec itself recursively
    if not os.path.isfile(_cand) or os.path.realpath(_cand) == _SELF:
        continue
    _spec = importlib.util.spec_from_file_location(
        "_shadowed_sitecustomize", _cand
    )
    if _spec is not None and _spec.loader is not None:
        _mod = importlib.util.module_from_spec(_spec)
        _spec.loader.exec_module(_mod)
    break
