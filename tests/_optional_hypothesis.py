"""Optional-hypothesis shim: the property-based tests use these stand-ins
so that a missing `hypothesis` package skips just those tests instead of
failing collection for the whole module."""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False
    _SKIP = pytest.mark.skip(reason="hypothesis not installed")

    def given(*_args, **_kwargs):
        def deco(fn):
            return _SKIP(fn)

        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    class _Strategies:
        """Placeholder so `st.lists(st.integers(...))` in decorators still
        evaluates; the values are never used because the test is skipped."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _Strategies()
