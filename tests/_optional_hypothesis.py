"""Optional-hypothesis shim: the property-based tests use these stand-ins
so that a missing `hypothesis` package skips just those tests instead of
failing collection for the whole module.

``REPRO_HYPOTHESIS_SCALE=N`` multiplies every ``max_examples`` by N —
tier-1 keeps the fast per-test budgets, and the nightly workflow reruns
the same suites 10x deeper without touching the test code.
"""
import os

import pytest

_SCALE = max(1, int(os.environ.get("REPRO_HYPOTHESIS_SCALE", "1") or "1"))

try:
    from hypothesis import given, strategies as st  # noqa: F401
    from hypothesis import settings as _hyp_settings

    HAVE_HYPOTHESIS = True

    def settings(*args, **kwargs):
        if "max_examples" in kwargs:
            kwargs["max_examples"] = kwargs["max_examples"] * _SCALE
        return _hyp_settings(*args, **kwargs)

except ImportError:
    HAVE_HYPOTHESIS = False
    _SKIP = pytest.mark.skip(reason="hypothesis not installed")

    def given(*_args, **_kwargs):
        def deco(fn):
            return _SKIP(fn)

        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    class _Strategies:
        """Placeholder so `st.lists(st.integers(...))` in decorators still
        evaluates; the values are never used because the test is skipped."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _Strategies()
