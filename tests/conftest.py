"""Shared pytest fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches
must see exactly 1 device (the 512-device override lives only in
launch/dryrun.py; multi-device executor tests use subprocesses)."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
