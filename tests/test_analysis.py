"""HLO collective parser + roofline term unit tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hlo import collective_bytes
from repro.analysis.roofline import Roofline, PEAK_FLOPS, HBM_BW, ICI_LINK_BW


def test_parse_synthetic_hlo():
    hlo = """
HloModule m
ENTRY e {
  %p0 = f32[128,256]{1,0} parameter(0)
  %ar = f32[128,256]{1,0} all-reduce(f32[128,256]{1,0} %p0), replica_groups={}
  %ag = bf16[16,64]{1,0} all-gather(bf16[8,64]{1,0} %x), dimensions={0}
  %cp = f32[32]{0} collective-permute(f32[32]{0} %y), source_target_pairs={{0,1}}
  %dn = f32[32]{0} all-reduce-done(f32[32]{0} %cp)
}
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 128 * 256 * 4
    assert out["all-gather"] == 8 * 64 * 2       # operand, not result
    assert out["collective-permute"] == 32 * 4
    assert out["total"] == sum(
        out[k] for k in ("all-reduce", "all-gather", "collective-permute")
    )
    assert out["count"] == 3


def test_parse_real_compiled_module():
    """Parse an actual XLA-compiled module containing a psum."""
    mesh = jax.make_mesh((1,), ("d",))

    def f(x):
        return jax.lax.psum(x, "d")

    shmapped = jax.shard_map(
        f, mesh=mesh, in_specs=jax.sharding.PartitionSpec("d"),
        out_specs=jax.sharding.PartitionSpec(), check_vma=False,
    )
    compiled = jax.jit(shmapped).lower(
        jax.ShapeDtypeStruct((4, 8), jnp.float32)
    ).compile()
    out = collective_bytes(compiled.as_text())
    # a 1-device psum may fold away; the parser must simply not crash and
    # return a well-formed dict
    assert "total" in out and out["total"] >= 0


def test_roofline_terms_and_bottleneck():
    r = Roofline(
        flops=PEAK_FLOPS,            # exactly 1 second of compute
        bytes_accessed=HBM_BW / 2,   # 0.5 s
        collective={"total": int(ICI_LINK_BW / 4)},  # 0.25 s
        chips=256,
        model_flops=PEAK_FLOPS * 256 * 0.5,  # useful ratio 0.5
    ).finalize()
    assert r.bottleneck == "compute"
    np.testing.assert_allclose(r.t_compute, 1.0)
    np.testing.assert_allclose(r.t_memory, 0.5)
    np.testing.assert_allclose(r.t_collective, 0.25)
    np.testing.assert_allclose(r.useful_ratio, 0.5)
    np.testing.assert_allclose(r.roofline_frac, 0.5)


def test_model_flops_counts_active_for_moe():
    from repro.analysis.roofline import model_flops
    from repro.configs import ARCHS
    from repro.models.config import SHAPES

    moe = ARCHS["phi3.5-moe-42b-a6.6b"]
    dense_equiv = moe.param_count()
    active = moe.active_param_count()
    mf = model_flops(moe, SHAPES["train_4k"])
    assert mf == 6.0 * active * SHAPES["train_4k"].global_batch * 4096
    assert active < dense_equiv
