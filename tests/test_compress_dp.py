"""Int8-compressed data-parallel gradient sync in a real shard_map DP loop
(4 devices): must track the exact-psum run closely thanks to error
feedback. This is the multi-pod DCN-crossing sync trick (DESIGN.md §5).
"""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import AxisType, PartitionSpec as P
from repro.dist.compress import compressed_psum_mean, init_error

mesh = jax.make_mesh((4,), ("data",), axis_types=(AxisType.Auto,))
key = jax.random.key(0)
W0 = jax.random.normal(key, (16, 16)) * 0.3
X = jax.random.normal(jax.random.fold_in(key, 1), (32, 16))
Y = X @ (jax.random.normal(jax.random.fold_in(key, 2), (16, 16)) * 0.5)

def loss_fn(w, x, y):
    return jnp.mean((x @ w - y) ** 2)

def make_train(compress):
    def step(w, err, x, y):
        g = jax.grad(loss_fn)(w, x, y)
        if compress:
            gs, err = compressed_psum_mean({"w": g}, "data", err)
            g = gs["w"]
        else:
            g = jax.lax.pmean(g, "data")
        return w - 0.05 * g, err
    sh = jax.shard_map(step, mesh=mesh,
                       in_specs=(P(), {"w": P()}, P("data"), P("data")),
                       out_specs=(P(), {"w": P()}), check_vma=False)
    return jax.jit(sh)

losses = {}
finals = {}
for compress in (False, True):
    w = W0
    err = init_error({"w": jnp.zeros_like(W0)})
    step = make_train(compress)
    for i in range(60):
        w, err = step(w, err, X, Y)
    losses[compress] = float(loss_fn(w, X, Y))
    finals[compress] = np.asarray(w)

rel = float(np.abs(finals[True] - finals[False]).max()
            / max(np.abs(finals[False]).max(), 1e-9))
print("RESULT" + json.dumps({
    "loss_exact": losses[False], "loss_comp": losses[True], "w_rel": rel,
}))
"""


@pytest.mark.slow
def test_compressed_dp_training_tracks_exact():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        env=env, timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][-1]
    out = json.loads(line[len("RESULT"):])
    # compressed training converges to (nearly) the same solution
    assert out["loss_comp"] < out["loss_exact"] * 1.5 + 1e-3, out
    assert out["w_rel"] < 0.05, out
