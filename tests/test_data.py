"""Data pipeline: determinism, checkpointability, sharding."""
import numpy as np

from repro.configs import ARCHS
from repro.data.pipeline import DataState, SyntheticTokens


def test_deterministic_replay():
    cfg = ARCHS["tinyllama-1.1b"].smoke()
    d1 = SyntheticTokens(cfg, 4, 32, seed=3)
    batches = [d1.next_batch() for _ in range(5)]
    d2 = SyntheticTokens(cfg, 4, 32, seed=3)
    for b in batches:
        b2 = d2.next_batch()
        for k in b:
            np.testing.assert_array_equal(b[k], b2[k])


def test_state_restore_mid_stream():
    cfg = ARCHS["tinyllama-1.1b"].smoke()
    d1 = SyntheticTokens(cfg, 4, 32, seed=9)
    for _ in range(3):
        d1.next_batch()
    st = d1.state.to_dict()
    want = d1.next_batch()

    d2 = SyntheticTokens(cfg, 4, 32, seed=0)
    d2.state = DataState.from_dict(st)
    got = d2.next_batch()
    for k in want:
        np.testing.assert_array_equal(want[k], got[k])


def test_shard_slices_batch():
    cfg = ARCHS["tinyllama-1.1b"].smoke()
    d = SyntheticTokens(cfg, 8, 16, seed=1)
    b = d.next_batch()
    parts = [d.shard(b, r, 4) for r in range(4)]
    glued = np.concatenate([p["tokens"] for p in parts], axis=0)
    np.testing.assert_array_equal(glued, b["tokens"])


def test_family_specific_batches():
    vlm = ARCHS["qwen2-vl-2b"].smoke()
    b = SyntheticTokens(vlm, 2, 16, seed=0).next_batch()
    assert set(b) == {"embeds", "positions", "labels"}
    assert b["positions"].shape == (2, 16, 3)

    audio = ARCHS["musicgen-medium"].smoke()
    b = SyntheticTokens(audio, 2, 16, seed=0).next_batch()
    assert b["tokens"].shape == (2, 16, audio.n_codebooks)
    assert b["tokens"].max() < audio.vocab
