"""Sharding rule engine + gradient compression tests."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS
from repro.dist.compress import (
    compressed_psum_mean, init_error, quantize_roundtrip,
)
from repro.dist.sharding import param_axes, spec_for, tree_specs
from repro.models import init_lm


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def test_spec_divisibility_fallback():
    mesh = FakeMesh({"data": 16, "model": 16})
    # 12 heads refuse a 16-way split -> replicate that dim
    assert spec_for((2048, 12 * 128), ("embed", "qkv"), mesh) == P("data", "model")
    assert spec_for((2048, 12), ("embed", "heads"), mesh) == P("data", None)
    # vocab not divisible -> falls to None
    assert spec_for((50280,), ("vocab",), mesh) == P(None)
    assert spec_for((50432,), ("vocab",), mesh) == P("model")


def test_spec_axis_conflicts_resolved():
    mesh = FakeMesh({"data": 16, "model": 16})
    # cache: seq takes `model`, so kv_heads must not reuse it
    s = spec_for((8, 128, 32768, 16, 128),
                 ("layer", "batch", "cache_seq", "kv_heads", "none"), mesh)
    assert s == P(None, "data", "model", None, None)


def test_spec_multi_axis_batch():
    mesh = FakeMesh({"pod": 2, "data": 16, "model": 16})
    s = spec_for((256, 4096), ("batch", "seq"), mesh)
    assert s == P(("pod", "data"), None)
    # batch=1 (long_500k): nothing divides -> replicated
    s = spec_for((1, 4096), ("batch", "seq"), mesh)
    assert s == P(None, None)


@pytest.mark.parametrize("arch", ["qwen1.5-110b", "moonshot-v1-16b-a3b",
                                  "mamba2-130m", "zamba2-7b"])
def test_param_axes_cover_tree(arch):
    cfg = ARCHS[arch]
    pshapes = jax.eval_shape(
        lambda: init_lm(jax.random.key(0), cfg.smoke())
    )
    axes = param_axes(cfg.smoke())
    # every param leaf must have a logical-axes tuple of matching rank
    flat_p = jax.tree.leaves_with_path(pshapes)
    specs = tree_specs(axes, pshapes, FakeMesh({"data": 2, "model": 2}))
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_s) == len(flat_p)


def test_quantize_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.key(0), (512,)) * 3.0
    y = quantize_roundtrip(x)
    amax = float(jnp.max(jnp.abs(x)))
    assert float(jnp.max(jnp.abs(x - y))) <= amax / 127.0 + 1e-6


def test_error_feedback_unbiased_over_time():
    """With error feedback the MEAN transmitted value converges to the true
    gradient mean (bias doesn't accumulate)."""
    g = jax.random.normal(jax.random.key(1), (256,)) * 0.1
    err = init_error({"g": g})

    sent_acc = jnp.zeros_like(g)
    steps = 50
    for _ in range(steps):
        out, err = compressed_psum_mean({"g": g}, axis=None, err=err)
        sent_acc = sent_acc + out["g"]
    mean_sent = sent_acc / steps
    np.testing.assert_allclose(np.asarray(mean_sent), np.asarray(g),
                               atol=2e-4)
