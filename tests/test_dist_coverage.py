"""Coverage the dry-run relies on: spec_for over every (arch x shape) cell
lowered by launch/dryrun.py, and shard_act's no-op guarantee outside a mesh
context."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, SHAPES, cell_applicable, input_specs
from repro.dist.sharding import (
    batch_axes, cache_axes, opt_axes, param_axes, shard_act, spec_for,
    tree_specs,
)
from repro.models import init_lm
from repro.train.optimizer import adamw_init


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


POD = FakeMesh({"data": 16, "model": 16})
MULTIPOD = FakeMesh({"pod": 2, "data": 16, "model": 16})
DRYRUN_SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def _mesh_dim_product(entry, mesh):
    if entry is None:
        return 1
    axes = entry if isinstance(entry, tuple) else (entry,)
    out = 1
    for a in axes:
        out *= mesh.shape[a]
    return out


@pytest.mark.parametrize("mesh", [POD, MULTIPOD], ids=["pod", "multipod"])
@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_param_axes_all_archs_full_size(arch, mesh):
    """Every param leaf of every registered arch resolves to a spec whose
    sharded dims divide evenly — the in_shardings the dry-run jits with."""
    cfg = ARCHS[arch]
    pshapes = jax.eval_shape(lambda: init_lm(jax.random.key(0), cfg))
    axes = param_axes(cfg)
    specs = tree_specs(axes, pshapes, mesh)
    flat_shapes = jax.tree.leaves(pshapes)
    flat_specs = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_specs) == len(flat_shapes)
    for shape, spec in zip(flat_shapes, flat_specs):
        for dim, entry in zip(shape.shape, tuple(spec)):
            assert dim % _mesh_dim_product(entry, mesh) == 0

    # optimizer state mirrors the params plus a replicated scalar step
    oshapes = jax.eval_shape(lambda: adamw_init(pshapes))
    ospecs = tree_specs(opt_axes(axes), oshapes, mesh)
    assert len(jax.tree.leaves(ospecs, is_leaf=lambda x: isinstance(x, P))) \
        == len(jax.tree.leaves(oshapes))


@pytest.mark.parametrize("mesh", [POD, MULTIPOD], ids=["pod", "multipod"])
@pytest.mark.parametrize("shape_name", DRYRUN_SHAPES)
@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_spec_for_all_dryrun_cells(arch, shape_name, mesh):
    """batch_axes/cache_axes cover every input leaf of every dry-run cell,
    and the resolved specs split each dim evenly."""
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    ok, _ = cell_applicable(cfg, shape)
    if not ok:
        pytest.skip("cell not applicable (long-context spec)")
    batch = input_specs(cfg, shape)
    baxes = batch_axes(cfg, shape.kind)
    specs = tree_specs(baxes, batch, mesh)
    flat = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat) == len(jax.tree.leaves(batch))
    for leaf, spec in zip(jax.tree.leaves(batch), flat):
        for dim, entry in zip(leaf.shape, tuple(spec)):
            assert dim % _mesh_dim_product(entry, mesh) == 0

    # the global batch must actually be data-sharded whenever it divides
    if shape.kind != "decode" or not cfg.n_codebooks:
        tok_spec = specs["embeds"] if cfg.family == "vlm" and \
            shape.kind != "decode" else specs["tokens"]
        B = shape.global_batch
        dp = _mesh_dim_product(tuple(tok_spec)[0], mesh)
        if B % np.prod([v for k, v in mesh.shape.items() if k != "model"]) == 0:
            assert dp == np.prod(
                [v for k, v in mesh.shape.items() if k != "model"]
            )


def test_cache_axes_match_cache_tree():
    for arch in sorted(ARCHS):
        cfg = ARCHS[arch]
        from repro.models import make_cache

        cshape = jax.eval_shape(lambda c=cfg: make_cache(c, 8, 128))
        specs = tree_specs(cache_axes(cfg), cshape, POD)
        assert len(jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))) \
            == len(jax.tree.leaves(cshape))


def test_shard_act_noop_outside_mesh():
    """Model code calls shard_act unconditionally; with no ambient mesh it
    must return its input unchanged, traced or eager."""
    x = jnp.arange(24.0).reshape(2, 3, 4)
    y = shard_act(x, "batch", "seq", "act_embed")
    assert y is x  # identical object: literally a no-op
    # and under jit tracing
    f = jax.jit(lambda a: shard_act(a, "batch", "seq", "act_embed") * 2.0)
    np.testing.assert_allclose(np.asarray(f(x)), np.asarray(x) * 2.0)


def test_shard_act_applies_inside_mesh():
    mesh = jax.make_mesh((1,), ("data",))
    x = jnp.arange(8.0).reshape(2, 4)
    with jax.sharding.set_mesh(mesh):
        y = shard_act(x, "batch", "none")
    np.testing.assert_allclose(np.asarray(y), np.asarray(x))


def test_spec_for_rejects_rank_mismatch():
    with pytest.raises(ValueError):
        spec_for((4, 4), ("batch",), POD)
    with pytest.raises(KeyError):
        spec_for((4,), ("no-such-axis",), POD)
