"""Decode alignment contract: the split-KV flash-decode kernel (interpret
mode) and the masked-window oracle must match ref.attention_ref on the
visible window for Sq == 1, across cache lengths (0, block/bucket
boundaries, full cache) and head layouts (MHA and GQA)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_decode import flash_decode

KEY = jax.random.key(7)
S_MAX = 256
BLOCK = 64


def _qkv(B, Hq, Hkv, D, dtype=jnp.float32, salt=0):
    ks = jax.random.split(jax.random.fold_in(KEY, salt), 3)
    q = jax.random.normal(ks[0], (B, 1, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, S_MAX, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, S_MAX, Hkv, D), dtype)
    return q, k, v


@pytest.mark.parametrize("Hq,Hkv", [(4, 4), (8, 2)])  # MHA, GQA
@pytest.mark.parametrize(
    "cache_len",
    [0, 1, 62, 63, 64, 127, 128, 255],  # 0, block edges, bucket edges, full
)
def test_flash_decode_matches_ref_window(Hq, Hkv, cache_len):
    """window = cache_len existing entries + the freshly written token."""
    B, D = 2, 32
    q, k, v = _qkv(B, Hq, Hkv, D, salt=cache_len)
    window = cache_len + 1
    out = flash_decode(q, k, v, jnp.full((B,), window, jnp.int32),
                       block_k=BLOCK, interpret=True)
    want = ref.attention_ref(q, k[:, :window], v[:, :window], causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-6, rtol=2e-6)


def test_flash_decode_mixed_lengths_per_slot():
    q, k, v = _qkv(4, 8, 2, 64, salt=101)
    lens = [1, 64, 97, 256]
    out = flash_decode(q, k, v, jnp.asarray(lens, jnp.int32),
                       block_k=BLOCK, interpret=True)
    for i, L in enumerate(lens):
        want = ref.attention_ref(q[i:i + 1], k[i:i + 1, :L], v[i:i + 1, :L],
                                 causal=True)
        np.testing.assert_allclose(np.asarray(out[i:i + 1]),
                                   np.asarray(want), atol=2e-6, rtol=2e-6)


def test_flash_decode_idle_slots_emit_zeros():
    """window == 0 marks an idle serving slot: every KV block is skipped
    and the kernel writes exact zeros."""
    q, k, v = _qkv(3, 4, 4, 32, salt=5)
    out = flash_decode(q, k, v, jnp.asarray([0, 5, 0], jnp.int32),
                       block_k=BLOCK, interpret=True)
    assert float(jnp.abs(out[0]).max()) == 0.0
    assert float(jnp.abs(out[2]).max()) == 0.0
    assert float(jnp.abs(out[1]).max()) > 0.0


def test_flash_decode_bf16():
    q, k, v = _qkv(2, 8, 2, 64, dtype=jnp.bfloat16, salt=9)
    lens = jnp.asarray([100, 256], jnp.int32)
    out = flash_decode(q, k, v, lens, block_k=BLOCK, interpret=True)
    assert out.dtype == jnp.bfloat16
    for i, L in enumerate([100, 256]):
        want = ref.attention_ref(q[i:i + 1], k[i:i + 1, :L], v[i:i + 1, :L],
                                 causal=True)
        np.testing.assert_allclose(
            np.asarray(out[i:i + 1], np.float32),
            np.asarray(want, np.float32), atol=2e-2, rtol=2e-2,
        )


def test_decode_ref_oracle_matches_window():
    """The padded-cache jnp oracle (what CPU serving runs) equals
    attention_ref on the visible slice."""
    q, k, v = _qkv(3, 8, 2, 32, salt=13)
    lens = [7, 130, 256]
    out = ref.decode_ref(q, k, v, jnp.asarray(lens, jnp.int32))
    for i, L in enumerate(lens):
        want = ref.attention_ref(q[i:i + 1], k[i:i + 1, :L], v[i:i + 1, :L],
                                 causal=True)
        np.testing.assert_allclose(np.asarray(out[i:i + 1]),
                                   np.asarray(want), atol=2e-5, rtol=2e-5)


def test_ops_attention_routes_decode_impls():
    """ops.attention with Sq==1 + lengths: every impl spelling lands on a
    window-masked path (kernel or oracle), and they agree."""
    q, k, v = _qkv(2, 4, 2, 32, salt=21)
    lens = jnp.asarray([33, 200], jnp.int32)
    o_kernel = ops.attention(q, k, v, causal=False, lengths=lens,
                             impl="decode_interpret")
    o_ref = ops.attention(q, k, v, causal=False, lengths=lens,
                          impl="decode_ref")
    o_auto = ops.attention(q, k, v, causal=False, lengths=lens, impl="auto")
    o_norm = ops.attention(q, k, v, causal=False, lengths=lens, impl="ref")
    np.testing.assert_allclose(np.asarray(o_kernel), np.asarray(o_ref),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_array_equal(np.asarray(o_auto), np.asarray(o_ref))
    np.testing.assert_array_equal(np.asarray(o_norm), np.asarray(o_ref))


def test_decode_block_k_table():
    from repro.core.autotune import decode_block_k

    assert decode_block_k(4096, 64) == 512
    assert decode_block_k(4096, 128) == 256
    assert decode_block_k(4096, 256) == 128
    assert decode_block_k(64, 64) == 64      # clamped to the cache bucket
    bk = decode_block_k(96, 64)              # non-power-of-two bucket
    assert 96 % bk == 0
