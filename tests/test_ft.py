"""Fault-tolerance drills: checkpoint roundtrip, failure + resume
bit-determinism, async checkpointing, elastic restore."""
import json
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.ft import checkpoint as ckpt


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": {"w": jnp.arange(12.0).reshape(3, 4)},
        "b": jnp.int32(7),
    }
    ckpt.save(str(tmp_path), 5, tree, extra={"note": "x"})
    out, extra, step = ckpt.restore(str(tmp_path))
    assert step == 5 and extra["note"] == "x"
    np.testing.assert_array_equal(out["a"]["w"], np.asarray(tree["a"]["w"]))
    np.testing.assert_array_equal(out["b"], 7)


def test_checkpoint_gc_keeps_latest(tmp_path):
    tree = {"w": jnp.zeros(3)}
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, tree, keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 5
    steps = sorted(os.listdir(tmp_path))
    assert len(steps) == 2


def test_async_checkpoint(tmp_path):
    tree = {"w": jnp.ones((64, 64))}
    t = ckpt.save(str(tmp_path), 1, tree, async_=True)
    t.join(timeout=30)
    out, _, _ = ckpt.restore(str(tmp_path))
    np.testing.assert_array_equal(out["w"], np.ones((64, 64)))


def _run_train(args, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.train"] + args,
        capture_output=True, text=True, env=env, timeout=timeout,
    )


@pytest.mark.slow
def test_failure_resume_bit_determinism(tmp_path):
    """Train A: 30 uninterrupted steps. Train B: killed at step 20,
    restarted with --resume. Param fingerprints must match exactly —
    the launcher-level FT contract."""
    common = ["--arch", "tinyllama-1.1b", "--preset", "tiny",
              "--steps", "30", "--batch", "4", "--seq", "64",
              "--ckpt-every", "10", "--log-every", "30"]
    mA = str(tmp_path / "a.json")
    r = _run_train(common + ["--ckpt-dir", str(tmp_path / "ckA"),
                             "--metrics-out", mA])
    assert r.returncode == 0, r.stderr[-3000:]

    ckB = str(tmp_path / "ckB")
    mB = str(tmp_path / "b.json")
    r = _run_train(common + ["--ckpt-dir", ckB, "--fail-at-step", "25"])
    assert r.returncode != 0 and "simulated node failure" in r.stderr
    r = _run_train(common + ["--ckpt-dir", ckB, "--resume",
                             "--metrics-out", mB])
    assert r.returncode == 0, r.stderr[-3000:]

    a = json.load(open(mA))
    b = json.load(open(mB))
    assert a["fingerprint"] == pytest.approx(b["fingerprint"], rel=1e-6), (
        "resumed run diverged from uninterrupted run"
    )
    assert a["history"][-1]["loss"] == pytest.approx(
        b["history"][-1]["loss"], rel=1e-5
    )


def test_elastic_restore_resharding(tmp_path):
    """Restore under different shardings (mesh changed between save and
    restore) must produce identical values."""
    tree = {"w": jnp.arange(64.0).reshape(8, 8)}
    ckpt.save(str(tmp_path), 1, tree)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec("data", None))}
    out, _, _ = ckpt.restore(str(tmp_path), shardings=sh)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.arange(64.0).reshape(8, 8))
    assert out["w"].sharding == sh["w"]
