"""Integration tests: GLB scheduler on the paper's problems (sim mode).

The paper's determinacy claim (§2.1): same input => same result under ANY
placement, parameters, or schedule. We assert exactly that against
sequential oracles.
"""
import numpy as np
import pytest

from repro.core import GLB, GLBParams, run_sim
from repro.problems.bc import bc_problem
from repro.problems.fib import fib_problem, fib_oracle
from repro.problems.rmat import brandes_bc_oracle, rmat_graph
from repro.problems.uts import uts_oracle, uts_problem


@pytest.mark.parametrize("P", [1, 2, 4, 8])
def test_fib_any_place_count(P):
    glb = GLB(fib_problem(16), GLBParams(n=16, steal_k=16), P=P)
    assert int(glb.run(seed=0)) == fib_oracle(16)


@pytest.mark.parametrize(
    "params",
    [
        GLBParams(n=8, w=1, z=1, steal_k=4),
        GLBParams(n=64, w=4, z=3, steal_k=64),
        GLBParams(n=256, w=0, z=0, steal_k=16),   # pure-lifeline mode
        GLBParams(n=32, w=2, z=2, steal_k=8, min_give=4),
    ],
)
def test_uts_param_invariance(params):
    """Any w/z/n/K must give the identical count (paper determinacy)."""
    oracle = uts_oracle(b0=4.0, depth=6, seed=19)
    glb = GLB(uts_problem(depth=6), params, P=4)
    assert int(glb.run(seed=0)) == oracle


@pytest.mark.parametrize("P", [1, 3, 4, 8])
def test_uts_place_count_invariance(P):
    oracle = uts_oracle(b0=4.0, depth=7, seed=19)
    glb = GLB(uts_problem(depth=7), GLBParams(n=64, steal_k=32), P=P)
    assert int(glb.run(seed=0)) == oracle
    st = glb.stats
    # conservation: every shipped item is received exactly once
    assert st["items_sent"].sum() == st["items_recv"].sum()
    # capacity audit: high-water mark leaves a packet of slack
    assert st["max_size"].max() + 32 <= 8192


def test_uts_seed_changes_schedule_not_result():
    oracle = uts_oracle(b0=4.0, depth=6, seed=19)
    p = uts_problem(depth=6)
    runs = [run_sim(p, 4, GLBParams(n=32, steal_k=16), seed=s) for s in (0, 1, 2)]
    assert all(int(r.result) == oracle for r in runs)


def test_uts_determinism_bitwise():
    p = uts_problem(depth=6)
    r1 = run_sim(p, 4, GLBParams(n=32), seed=5)
    r2 = run_sim(p, 4, GLBParams(n=32), seed=5)
    assert int(r1.supersteps) == int(r2.supersteps)
    for k in r1.stats:
        np.testing.assert_array_equal(r1.stats[k], r2.stats[k])


@pytest.mark.parametrize("static_init", [True, False])
def test_bc_vs_brandes_oracle(static_init):
    adj, n = rmat_graph(scale=5, seed=11)
    oracle = brandes_bc_oracle(adj)
    glb = GLB(
        bc_problem(adj, capacity=256, static_init=static_init),
        GLBParams(n=8, steal_k=8),
        P=4,
    )
    bc = np.asarray(glb.run(seed=0))
    np.testing.assert_allclose(bc, oracle, rtol=1e-4, atol=1e-3)


def test_bc_glb_beats_static_imbalance():
    """The paper's headline claim (Fig 6/8/10): GLB flattens the workload
    distribution vs static partitioning.

    We use the paper's own degenerate-imbalance construction (§2.6.1: "the
    work associated with one source vertex vs another could be dramatically
    different"): on a directed path graph the BFS from vertex i costs N-i
    sweeps, so a static partition gives place 0 ~N²/P·(1-1/2P) work and the
    last place almost none."""
    n = 96
    adj = np.zeros((n, n), np.float32)
    adj[np.arange(n - 1), np.arange(1, n)] = 1.0  # i -> i+1
    P = 8
    prob = bc_problem(adj, capacity=256)
    glb = run_sim(prob, P, GLBParams(n=4, steal_k=8), seed=0)
    static = run_sim(prob, P, GLBParams(n=4, no_steal=True), seed=0)
    np.testing.assert_allclose(
        np.asarray(glb.result), np.asarray(static.result), rtol=1e-4, atol=1e-3
    )
    w_glb = np.asarray(glb.stats["processed"], np.float64)
    w_static = np.asarray(static.stats["processed"], np.float64)
    assert w_glb.sum() >= w_static.sum() * 0.99  # same total work
    # paper Fig 6: std-dev collapses (4.027 -> 1.141 there; >=3x here)
    assert w_glb.std() <= w_static.std() / 3
    # and the makespan (supersteps ~ wall time) shrinks accordingly
    assert int(glb.supersteps) <= int(static.supersteps) * 0.6


def test_work_in_state_blocks_termination():
    """BC places with an in-progress vertex but empty bags must keep the
    run alive until the vertex completes (paper §2.6 state machine)."""
    adj, n = rmat_graph(scale=4, seed=2)
    oracle = brandes_bc_oracle(adj)
    # budget n=1: a vertex takes many supersteps; bags drain long before
    # the BFS finishes. An incorrect termination check would undercount.
    glb = GLB(bc_problem(adj, capacity=64), GLBParams(n=1, steal_k=4), P=4)
    bc = np.asarray(glb.run(seed=0))
    np.testing.assert_allclose(bc, oracle, rtol=1e-4, atol=1e-3)


def test_autotune_picks_converging_config():
    """Paper future-work (4): parameter auto-tuning via probe runs."""
    from repro.core.autotune import autotune
    from repro.problems.uts import uts_problem, uts_oracle
    from repro.core import GLBParams, run_sim

    prob = uts_problem(depth=6)
    res = autotune(prob, 4, w_grid=(0, 2), z_grid=(0,), n_grid=(32, 128),
                   seed=0)
    assert len(res.table) == 4
    # the tuned config must still compute the right answer
    out = run_sim(prob, 4, res.best, seed=1)
    assert int(out.result) == uts_oracle(depth=6)
    # and be no worse on the score than every probed alternative
    best_score = res.table[0][1] * res.table[0][0].n
    for params, steps, idle in res.table[1:]:
        assert best_score <= steps * params.n
