"""GLB-MoE expert placement balancing: load flattening + math invariance."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.models.glb_moe import glb_expert_rebalance, permute_expert_params
from repro.models.moe import moe_fwd, moe_init


def test_rebalance_flattens_skewed_load():
    # 16 experts on 4 ranks; experts 0..3 (all on rank 0) are hot
    counts = np.ones(16) * 10
    counts[:4] = 200
    perm = np.arange(16)
    res = glb_expert_rebalance(counts, perm, n_ranks=4, seed=0)
    assert res.loads_after.std() < res.loads_before.std() * 0.5, (
        res.loads_before, res.loads_after
    )
    # permutation stays a bijection
    assert sorted(res.perm.tolist()) == list(range(16))


def test_rebalance_noop_when_balanced():
    counts = np.ones(16) * 50
    perm = np.arange(16)
    res = glb_expert_rebalance(counts, perm, n_ranks=4)
    assert (res.perm == perm).all()
    assert res.swaps == []


def test_placement_permutation_preserves_math():
    """moe_fwd(expert_perm, permuted weights) must be numerically identical
    to the unpermuted layer — placement is transparent to the model."""
    cfg = dataclasses.replace(
        ARCHS["phi3.5-moe-42b-a6.6b"].smoke(), capacity_factor=8.0
    )
    key = jax.random.key(0)
    p = moe_init(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, cfg.d_model),
                          jnp.float32)
    y0, aux0 = moe_fwd(p, x, cfg)

    counts = np.asarray(aux0["expert_counts"])
    perm_old = np.arange(cfg.n_experts)
    res = glb_expert_rebalance(counts + np.arange(cfg.n_experts) * 5,
                               perm_old, n_ranks=2)
    p2 = dict(p)
    p2.update(permute_expert_params(
        {k: p[k] for k in ("wg", "wi", "wo")}, perm_old, res.perm))
    y1, aux1 = moe_fwd(p2, x, cfg, expert_perm=jnp.asarray(res.perm))
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=1e-5, atol=1e-5)


def test_serving_balancer_moves_queued_requests():
    from repro.models import init_lm
    from repro.serve.engine import Engine, GLBReplicaBalancer, Request

    cfg = ARCHS["tinyllama-1.1b"].smoke()
    params = init_lm(jax.random.key(0), cfg)
    engines = [Engine(cfg, params, max_slots=2, max_seq=64, pad_len=8)
               for _ in range(2)]
    bal = GLBReplicaBalancer(engines)
    reqs = [Request(rid=i, prompt=[3, 1 + i, 4], max_new=4)
            for i in range(8)]
    # dump everything on replica 0 — the balancer must spread it
    for r in reqs:
        bal.submit(r, rr=0)
    bal.run(max_steps=200)
    assert all(r.done for r in reqs)
    assert bal.moves > 0, "idle replica never stole work"
    assert engines[1].tokens_out > 0, "stolen requests never ran"
