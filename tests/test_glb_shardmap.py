"""Distributed executor == simulated scheduler, on 8 real host devices.

Run in a subprocess because XLA fixes the device count at first init and the
rest of the suite must see exactly 1 device.
"""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax
from repro.core import GLB, GLBParams, run_sim
from repro.problems.uts import uts_problem, uts_oracle
from repro.problems.fib import fib_problem, fib_oracle

assert len(jax.devices()) == 8
mesh = jax.make_mesh((8,), ("place",))
out = {}

prob = uts_problem(depth=6)
params = GLBParams(n=64, w=2, steal_k=32)
sim = run_sim(prob, 8, params, seed=0)
out["oracle"] = uts_oracle(depth=6)
out["sim"] = int(sim.result)
out["sim_steps"] = int(sim.supersteps)
for routing in ("dense", "lifeline"):
    glb = GLB(prob, params, mesh=mesh, mode="shard_map", routing=routing)
    r = glb.run(seed=0)
    out[routing] = int(r)
    out[routing + "_steps"] = glb.supersteps
    out[routing + "_stats_equal"] = all(
        np.array_equal(np.asarray(sim.stats[k]), np.asarray(glb.stats[k]))
        for k in sim.stats
    )

# fib via shard_map too (generic tail-split bag exercises packet masking)
fp = fib_problem(15)
fparams = GLBParams(n=8, steal_k=8)
fsim = run_sim(fp, 8, fparams, seed=0)
fglb = GLB(fp, fparams, mesh=mesh, mode="shard_map", routing="lifeline")
out["fib"] = int(fglb.run(seed=0))
out["fib_oracle"] = fib_oracle(15)
out["fib_sim"] = int(fsim.result)
print("RESULT" + json.dumps(out))
"""


@pytest.mark.slow
def test_shardmap_equals_sim_8_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True,
        text=True,
        env=env,
        timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][-1]
    out = json.loads(line[len("RESULT"):])
    assert out["sim"] == out["oracle"]
    for routing in ("dense", "lifeline"):
        assert out[routing] == out["oracle"]
        assert out[routing + "_steps"] == out["sim_steps"]
        assert out[routing + "_stats_equal"], (
            f"{routing} executor diverged from sim scheduler"
        )
    assert out["fib"] == out["fib_oracle"] == out["fib_sim"]
