"""Per-kernel validation: shape/dtype sweeps, interpret-mode Pallas vs the
pure-jnp oracles in kernels/ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mamba2_ssd import ssd_chunked
from repro.kernels.uts_expand import uts_expand
from repro.problems.uts import geom_thresholds

KEY = jax.random.key(42)


# ------------------------------------------------------- flash attention
@pytest.mark.parametrize(
    "B,Sq,Skv,Hq,Hkv,D",
    [
        (1, 128, 128, 4, 2, 64),    # GQA, square
        (2, 64, 64, 2, 2, 32),      # MHA, small
        (1, 1, 256, 8, 2, 64),      # decode: one query vs cache
        (1, 128, 384, 6, 3, 64),    # prefill continuation (Skv > Sq)
        (1, 256, 256, 4, 1, 128),   # MQA, full head_dim
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(B, Sq, Skv, Hq, Hkv, D, dtype):
    ks = jax.random.split(jax.random.fold_in(KEY, hash((Sq, Skv, Hq, D)) % (2**31)), 3)
    q = jax.random.normal(ks[0], (B, Sq, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, Skv, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, Skv, Hkv, D), dtype)
    out = flash_attention(q, k, v, causal=True, interpret=True,
                          block_q=64, block_k=64)
    want = ref.attention_ref(q, k, v, causal=True)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=tol, rtol=tol,
    )


def test_flash_attention_causal_block_skip_equality():
    """Square causal prefill where nearly half the kv blocks lie strictly
    above the diagonal: the pl.when block skip must drop them without
    changing the result (oracle equality in interpret mode)."""
    ks = jax.random.split(jax.random.fold_in(KEY, 777), 3)
    q = jax.random.normal(ks[0], (1, 512, 2, 32))
    k = jax.random.normal(ks[1], (1, 512, 2, 32))
    v = jax.random.normal(ks[2], (1, 512, 2, 32))
    out = flash_attention(q, k, v, causal=True, interpret=True,
                          block_q=64, block_k=64)  # 28/64 blocks skipped
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_non_causal():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 32))
    k = jax.random.normal(ks[1], (1, 128, 2, 32))
    v = jax.random.normal(ks[2], (1, 128, 2, 32))
    out = flash_attention(q, k, v, causal=False, interpret=True,
                          block_q=64, block_k=64)
    want = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


# ------------------------------------------------------------ mamba2 ssd
@pytest.mark.parametrize(
    "Bt,T,H,P,N,chunk",
    [
        (1, 128, 2, 64, 64, 32),
        (2, 64, 4, 32, 128, 64),
        (1, 256, 3, 64, 64, 64),
        (1, 64, 1, 128, 64, 16),
    ],
)
def test_ssd_matches_scan(Bt, T, H, P, N, chunk):
    ks = jax.random.split(jax.random.fold_in(KEY, hash((T, H, P, N)) % (2**31)), 5)
    x = jax.random.normal(ks[0], (Bt, T, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bt, T, H))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    B = jax.random.normal(ks[3], (Bt, T, N))
    C = jax.random.normal(ks[4], (Bt, T, N))
    y, h = ssd_chunked(x, dt, A, B, C, chunk=chunk, interpret=True)
    yr, hr = ref.ssd_ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=5e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), atol=5e-5, rtol=1e-4)


def test_ssd_bf16_inputs():
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (1, 64, 2, 32), jnp.bfloat16)
    dt = (jax.nn.softplus(jax.random.normal(ks[1], (1, 64, 2))) * 0.1)
    A = -jnp.exp(jax.random.normal(ks[2], (2,)))
    B = jax.random.normal(ks[3], (1, 64, 64))
    C = jax.random.normal(ks[4], (1, 64, 64))
    y, h = ssd_chunked(x, dt, A, B, C, chunk=32, interpret=True)
    yr, hr = ref.ssd_ref(x, dt, A, B, C)
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(yr, np.float32), atol=0.05, rtol=0.05
    )


# ------------------------------------------------------------ uts_expand
@pytest.mark.parametrize("M,width,block_m", [(128, 64, 128), (256, 32, 64), (64, 8, 64)])
def test_uts_expand_matches_ref(M, width, block_m):
    ks = jax.random.split(jax.random.fold_in(KEY, M + width), 3)
    d0 = jax.random.randint(ks[0], (M,), 0, 1 << 30, jnp.int32).astype(jnp.uint32)
    d1 = jax.random.randint(ks[1], (M,), 0, 1 << 30, jnp.int32).astype(jnp.uint32)
    base = jax.random.randint(ks[2], (M,), 0, 100, jnp.int32)
    thr = jnp.asarray(geom_thresholds(4.0))
    cd0, cd1, m = uts_expand(d0, d1, base, thr, width=width,
                             block_m=block_m, interpret=True)
    rd0, rd1, rm = ref.uts_expand_ref(d0, d1, base, thr, width)
    np.testing.assert_array_equal(np.asarray(cd0), np.asarray(rd0))
    np.testing.assert_array_equal(np.asarray(cd1), np.asarray(rd1))
    np.testing.assert_array_equal(np.asarray(m), np.asarray(rm))


def test_uts_expand_matches_python_oracle():
    """Kernel hashing must be bit-identical to the sequential python oracle
    (the same functions the GLB UTS problem uses)."""
    from repro.problems.uts import child_hash

    d0 = jnp.asarray([12345], jnp.uint32)
    d1 = jnp.asarray([67890], jnp.uint32)
    base = jnp.asarray([0], jnp.int32)
    thr = jnp.asarray(geom_thresholds(4.0))
    cd0, cd1, m = uts_expand(d0, d1, base, thr, width=16, interpret=True)
    pd0, pd1 = child_hash(np.uint32(12345), np.uint32(67890),
                          np.arange(16, dtype=np.uint32), np)
    np.testing.assert_array_equal(np.asarray(cd0)[0], pd0)
    np.testing.assert_array_equal(np.asarray(cd1)[0], pd1)


# --------------------------------------------------------------- moe_gmm
from _optional_hypothesis import given, settings, st

from repro.kernels.moe_gmm import gmm


@pytest.mark.parametrize(
    "T,D,F,E,bt,bf",
    [
        (256, 64, 128, 4, 64, 64),
        (128, 32, 64, 8, 128, 64),
        (512, 16, 32, 2, 64, 32),
    ],
)
def test_gmm_matches_ref(T, D, F, E, bt, bf):
    ks = jax.random.split(jax.random.fold_in(KEY, T + E), 3)
    x = jax.random.normal(ks[0], (T, D), jnp.float32)
    w = jax.random.normal(ks[1], (E, D, F), jnp.float32)
    # random group sizes summing to <= T (tail rows belong to no expert
    # per ref semantics: searchsorted clips to the last expert, so make
    # sizes sum exactly to T)
    raw = np.asarray(jax.random.dirichlet(ks[2], jnp.ones(E)) * T, np.int64)
    raw[-1] = T - raw[:-1].sum()
    gs = jnp.asarray(raw, jnp.int32)
    out = gmm(x, w, gs, block_t=bt, block_f=bf, interpret=True)
    want = ref.gmm_ref(x, w, gs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-4, rtol=2e-4)


@settings(max_examples=15, deadline=None)
@given(sizes=st.lists(st.integers(0, 64), min_size=2, max_size=6))
def test_gmm_group_edges(sizes):
    """Empty groups and group boundaries inside a tile must be exact."""
    E = len(sizes)
    T = 128
    total = sum(sizes)
    if total > T or total == 0:
        return
    sizes = list(sizes)
    sizes[-1] += T - total  # pad the last group to fill T
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (T, 16), jnp.float32)
    w = jax.random.normal(ks[1], (E, 16, 32), jnp.float32)
    gs = jnp.asarray(sizes, jnp.int32)
    out = gmm(x, w, gs, block_t=64, block_f=32, interpret=True)
    want = ref.gmm_ref(x, w, gs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-4, rtol=2e-4)


def test_rank_within_expert_matches_cumsum():
    """The sort-based queue ranking (EXPERIMENTS §Perf M2) must equal the
    dense one-hot cumsum definition."""
    from repro.models.moe import _rank_within_expert

    E = 8
    ids = jax.random.randint(KEY, (500,), 0, E)
    pos, counts = _rank_within_expert(ids, E)
    onehot = jax.nn.one_hot(ids, E)
    want = ((jnp.cumsum(onehot, axis=0) - onehot) * onehot).sum(-1)
    np.testing.assert_array_equal(np.asarray(pos), np.asarray(want, np.int32))
    np.testing.assert_array_equal(
        np.asarray(counts), np.asarray(onehot.sum(0), np.int32)
    )
