"""KVPool invariants: alloc/extend/free round-trips, deterministic
allocation order, refcount/COW fork semantics, double-free guards, and
the occupancy/fragmentation stats the scheduler and balancer consume."""
import pytest

from repro.serve.kvpool import KVPool, PoolExhausted


def test_alloc_free_roundtrip():
    pool = KVPool(num_blocks=8, block_size=4)
    t = pool.alloc(0, 10)                 # 3 blocks
    assert t == [0, 1, 2]
    assert pool.free_blocks == 5
    assert pool.seq_len(0) == 10
    pool.free(0)
    assert pool.free_blocks == 8
    assert not pool.has_seq(0)
    # freed blocks are reused lowest-id-first (deterministic)
    assert pool.alloc(1, 4) == [0]


def test_deterministic_allocation_order():
    pool = KVPool(num_blocks=8, block_size=4)
    a = pool.alloc(0, 8)      # [0, 1]
    b = pool.alloc(1, 8)      # [2, 3]
    c = pool.alloc(2, 8)      # [4, 5]
    assert (a, b, c) == ([0, 1], [2, 3], [4, 5])
    pool.free(1)              # 2, 3 return
    pool.free(0)              # 0, 1 return
    # next alloc takes the lowest free ids regardless of free order
    assert pool.alloc(3, 12) == [0, 1, 2]


def test_extend_allocates_only_new_blocks():
    pool = KVPool(num_blocks=8, block_size=4)
    pool.alloc(0, 3)                      # 1 block, partially filled
    new, copies = pool.extend(0, 4)       # still inside block 0
    assert new == [] and copies == []
    new, copies = pool.extend(0, 9)       # needs 2 more
    assert len(new) == 2 and copies == []
    assert pool.block_table(0) == [0, 1, 2]
    assert pool.seq_len(0) == 9
    # shrink/no-op extends change nothing
    assert pool.extend(0, 5) == ([], [])
    assert pool.seq_len(0) == 9


def test_reserve_vs_advance_split():
    """reserve grows capacity without counting tokens as written (the
    scheduler's lookahead); advance records actual writes; stats report
    the gap as fragmentation."""
    pool = KVPool(num_blocks=8, block_size=4)
    pool.alloc(0, 3)
    new, copies = pool.reserve(0, 10)         # 2 extra blocks reserved
    assert len(new) == 2 and copies == []
    assert pool.seq_len(0) == 3               # written length unchanged
    assert pool.capacity(0) == 12
    assert pool.stats().fragmentation == pytest.approx(1 - 3 / 12)
    pool.advance(0, 10)
    assert pool.seq_len(0) == 10
    assert pool.stats().fragmentation == pytest.approx(1 - 10 / 12)
    with pytest.raises(ValueError):
        pool.advance(0, 13)                   # beyond reserved capacity
    pool.advance(0, 5)                        # never shrinks
    assert pool.seq_len(0) == 10


def test_exhaustion_is_atomic():
    pool = KVPool(num_blocks=4, block_size=4)
    pool.alloc(0, 12)                     # 3 blocks
    with pytest.raises(PoolExhausted):
        pool.alloc(1, 8)                  # needs 2, only 1 free
    assert pool.free_blocks == 1          # nothing leaked
    with pytest.raises(PoolExhausted):
        pool.extend(0, 24)                # needs 3 more
    assert pool.block_table(0) == [0, 1, 2]
    assert pool.seq_len(0) == 12


def test_fork_shares_blocks_and_cow_on_write():
    pool = KVPool(num_blocks=8, block_size=4)
    pool.alloc(0, 6)                      # blocks [0, 1], block 1 partial
    child = pool.fork(0, 1)
    assert child == [0, 1]                # shared prefix cached once
    assert pool.free_blocks == 6          # fork allocates nothing
    # the child's next write lands in shared partial block 1 -> COW
    new, copies = pool.extend(1, 7)
    assert copies == [(1, 2)]             # copy old tail into fresh block
    assert new == []                      # still inside the (new) tail block
    assert pool.block_table(1) == [0, 2]
    assert pool.block_table(0) == [0, 1]  # parent untouched
    assert pool.free_blocks == 5          # COW consumed one block
    # block 0 stays shared: freeing the child keeps it live
    pool.free(1)
    assert pool.free_blocks == 6          # only block 2 returned
    pool.free(0)
    assert pool.free_blocks == 8


def test_cow_covers_every_shared_block_in_write_range():
    """Regression: a reservation spanning multiple already-allocated
    shared blocks (forked child of a parent with lookahead reservation)
    must COW ALL of them, not just the tail block."""
    pool = KVPool(num_blocks=16, block_size=4)
    pool.alloc(0, 6)
    pool.reserve(0, 12)                   # parent table [0, 1, 2]
    pool.fork(0, 1)
    new, copies = pool.extend(1, 11)      # child writes positions 6..10
    # blocks 1 (pos 4-7) and 2 (pos 8-11) are written -> both COW'd;
    # block 0 (pos 0-3) is read-only and stays shared
    assert sorted(c[0] for c in copies) == [1, 2]
    assert new == []
    child = pool.block_table(1)
    parent = pool.block_table(0)
    assert child[0] == parent[0] == 0
    assert child[1] != parent[1] and child[2] != parent[2]
    pool.free(0)
    pool.free(1)
    assert pool.free_blocks == 16


def test_cow_skipped_on_block_boundary():
    """A fork whose next write starts a brand-new block needs no copy."""
    pool = KVPool(num_blocks=8, block_size=4)
    pool.alloc(0, 8)                      # exactly 2 full blocks
    pool.fork(0, 1)
    new, copies = pool.extend(1, 9)
    assert copies == []                   # nothing shared is written
    assert len(new) == 1


def test_double_free_raises():
    pool = KVPool(num_blocks=4, block_size=4)
    pool.alloc(0, 4)
    pool.free(0)
    with pytest.raises(KeyError):
        pool.free(0)
    pool.alloc(2, 4)
    with pytest.raises(ValueError):
        pool.alloc(2, 4)                  # re-alloc of a live sid


def test_stats_occupancy_and_fragmentation():
    pool = KVPool(num_blocks=10, block_size=8)
    s = pool.stats()
    assert s.occupancy == 0.0 and s.fragmentation == 0.0
    pool.alloc(0, 9)                      # 2 blocks for 9 tokens
    s = pool.stats()
    assert s.live_blocks == 2 and s.free_blocks == 8
    assert s.occupancy == pytest.approx(0.2)
    assert s.fragmentation == pytest.approx(1 - 9 / 16)
    pool.extend(0, 16)                    # fills both blocks exactly
    assert pool.stats().fragmentation == 0.0
    pool.free(0)
    assert pool.stats().occupancy == 0.0
