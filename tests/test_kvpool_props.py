"""Property-based hardening of the KV pool + radix prefix cache: random
op sequences (alloc / reserve / advance / extend / fork / free /
insert-on-release / evict) driven through one interpreter that checks,
after EVERY op:

* refcount conservation — each block's refcount equals the number of
  sequence tables plus radix-tree nodes that reference it, and the free
  heap holds exactly the refcount-0 blocks;
* free + seq-referenced + cached == capacity — cached being the blocks
  only the tree references (the pool's reclaimable accounting);
* COW isolation — a shadow memory records every token written through a
  block table; after any op, every sequence reads back exactly its own
  tokens, so no write can ever leak through a block shared with an
  unrelated sequence (and prefix-cache hits hand back blocks whose
  content IS the matched tokens);
* deterministic replay — the same op sequence on a fresh pool reproduces
  identical tables, free-heap order, and stats.

Runs in tier-1 twice: hypothesis-driven when the package is present
(CI), and over fixed-seed numpy op streams through the same interpreter
so the logic is exercised even under the optional-hypothesis shim.
"""
import numpy as np
import pytest

from repro.serve.kvpool import KVPool, PoolExhausted
from repro.serve.radix import RadixPrefixCache

from _optional_hypothesis import HAVE_HYPOTHESIS, given, settings, st

NUM_BLOCKS = 12
BS = 4
MAX_SEQ = NUM_BLOCKS * BS

# A few shared prompt stems so random sequences actually collide in the
# radix tree (pure-random tokens would never produce a prefix hit).
_STEMS = [
    [7, 3, 9, 2, 5, 8, 6, 4, 1, 2, 3, 4, 9, 9, 8, 7],
    [7, 3, 9, 2, 1, 1, 2, 2, 3, 3, 4, 4],
    [5, 5, 5, 5, 6, 6, 6, 6],
]


def _tokens_for(sid: int, a: int, n: int):
    """Deterministic token stream for sequence ``sid``: a shared stem
    followed by a sid-unique tail (positions are content, so shadow-memory
    readback detects any cross-sequence block aliasing)."""
    stem = _STEMS[a % len(_STEMS)]
    out = list(stem) + [100 + sid * 7 + k % 5 for k in range(n)]
    return out[:n] if n <= len(out) else out + [
        200 + sid + k for k in range(n - len(out))
    ]


class _Harness:
    """Interprets (op, a, b) triples against a pool (+ optional radix
    cache) while mirroring every write in a shadow block memory."""

    def __init__(self, with_cache: bool):
        self.pool = KVPool(NUM_BLOCKS, BS)
        self.cache = RadixPrefixCache(self.pool) if with_cache else None
        self.mem = {}                  # (block, off) -> token
        self.toks = {}                 # sid -> full planned token stream
        self.next_sid = 0
        self.trace = []                # replay-determinism fingerprint

    # ----------------------------------------------------------- shadow ops
    def _write(self, sid: int, lo: int, hi: int):
        table = self.pool.block_table(sid)
        for p in range(lo, hi):
            self.mem[(table[p // BS], p % BS)] = self.toks[sid][p]

    def _apply_copies(self, copies):
        for src, dst in copies:
            for off in range(BS):
                if (src, off) in self.mem:
                    self.mem[(dst, off)] = self.mem[(src, off)]

    # ------------------------------------------------------------------ ops
    def step(self, op: int, a: int, b: int):
        live = sorted(self.pool._tables)
        if op == 0:                                   # alloc + write prompt
            n = 1 + a % 20
            sid = self.next_sid
            self.next_sid += 1
            self.toks[sid] = _tokens_for(sid, b, MAX_SEQ)
            if self.cache is not None:
                m = self.cache.fork(sid, self.toks[sid][:n])
                if m == 0:
                    self.pool.alloc(sid, 0)
                try:
                    copies = self.pool.reserve(sid, n)[1]
                except PoolExhausted:
                    self.pool.free(sid)
                    del self.toks[sid]
                    return
                self._apply_copies(copies)
                self.pool.advance(sid, n)
                self._write(sid, m, n)
            else:
                try:
                    self.pool.alloc(sid, n)
                except PoolExhausted:
                    del self.toks[sid]
                    return
                self._write(sid, 0, n)
        elif op == 1 and live:                        # extend (reserve+write)
            sid = live[a % len(live)]
            w = self.pool.seq_len(sid)
            n = min(w + 1 + b % 9, MAX_SEQ)
            try:
                _, copies = self.pool.extend(sid, n)
            except PoolExhausted:
                return
            self._apply_copies(copies)
            self._write(sid, w, n)
        elif op == 2 and live:                        # fork (pool-level COW)
            parent = live[a % len(live)]
            sid = self.next_sid
            self.next_sid += 1
            self.pool.fork(parent, sid)
            self.toks[sid] = list(
                self.toks[parent][: self.pool.seq_len(parent)]
            ) + _tokens_for(sid, b, MAX_SEQ)
            self.toks[sid] = self.toks[sid][:MAX_SEQ]
        elif op == 3 and live:                        # free (maybe via cache)
            sid = live[a % len(live)]
            if self.cache is not None and b % 2 == 0:
                w = self.pool.seq_len(sid)
                self.cache.insert(self.toks[sid][:w],
                                  self.pool.block_table(sid), w)
            self.pool.free(sid)
        elif op == 4 and live:                        # reserve lookahead
            sid = live[a % len(live)]
            n = min(self.pool.seq_len(sid) + 1 + b % 8, MAX_SEQ)
            try:
                _, copies = self.pool.reserve(sid, n)
            except PoolExhausted:
                return
            self._apply_copies(copies)
        elif op == 5 and self.cache is not None:      # explicit eviction
            self.cache.evict(1 + a % 4)
        self.trace.append(
            (op, sorted((s, tuple(t)) for s, t in self.pool._tables.items()),
             sorted(self.pool._free))
        )

    # ----------------------------------------------------------- invariants
    def check(self):
        pool, cache = self.pool, self.cache
        refs = [0] * NUM_BLOCKS
        for table in pool._tables.values():
            for blk in table:
                refs[blk] += 1
        tree_blocks = set()
        if cache is not None:
            stack = [cache.root]
            while stack:
                nd = stack.pop()
                for blk in nd.blocks:
                    refs[blk] += 1
                    assert blk not in tree_blocks, \
                        f"block {blk} owned by two tree nodes"
                    tree_blocks.add(blk)
                stack.extend(nd.children.values())
        # refcount conservation + free heap == the refcount-0 blocks
        assert refs == pool._ref, (refs, pool._ref)
        assert sorted(pool._free) == [
            blk for blk in range(NUM_BLOCKS) if refs[blk] == 0
        ]
        # free + seq-referenced + cached == capacity
        cached = pool.cached_blocks
        seq_ref = (NUM_BLOCKS - pool.free_blocks) - cached
        assert pool.free_blocks + seq_ref + cached == NUM_BLOCKS
        assert cached == sum(
            1 for blk in tree_blocks if pool.refcount(blk) == 1
        )
        # COW isolation: every sequence reads back exactly its own tokens
        for sid, table in pool._tables.items():
            for p in range(pool.seq_len(sid)):
                got = self.mem.get((table[p // BS], p % BS))
                assert got == self.toks[sid][p], (
                    f"seq {sid} pos {p}: read {got}, "
                    f"expected {self.toks[sid][p]} — block aliasing"
                )


def _run_ops(ops, with_cache: bool):
    h = _Harness(with_cache)
    for op, a, b in ops:
        h.step(int(op) % 6, int(a), int(b))
        h.check()
    return h


def _op_stream(seed: int, n: int = 90):
    rng = np.random.RandomState(seed)
    return list(zip(rng.randint(0, 6, n), rng.randint(0, 64, n),
                    rng.randint(0, 64, n)))


@pytest.mark.parametrize("with_cache", [False, True])
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_random_op_streams_hold_invariants(seed, with_cache):
    """Fixed-seed streams through the interpreter — tier-1 coverage even
    when hypothesis is absent (the shim skips only the @given tests)."""
    _run_ops(_op_stream(seed), with_cache)


@pytest.mark.parametrize("with_cache", [False, True])
def test_deterministic_replay(with_cache):
    """Same ops on a fresh pool => identical tables, free-heap order, and
    stats at every step (the allocator is fully deterministic)."""
    ops = _op_stream(7)
    h1 = _run_ops(ops, with_cache)
    h2 = _run_ops(ops, with_cache)
    assert h1.trace == h2.trace
    assert h1.pool.stats() == h2.pool.stats()


@given(ops=st.lists(
    st.tuples(st.integers(0, 5), st.integers(0, 63), st.integers(0, 63)),
    max_size=60,
))
@settings(max_examples=40, deadline=None)
def test_pool_props_hypothesis(ops):
    _run_ops(ops, with_cache=False)


@given(ops=st.lists(
    st.tuples(st.integers(0, 5), st.integers(0, 63), st.integers(0, 63)),
    max_size=60,
))
@settings(max_examples=40, deadline=None)
def test_pool_cache_props_hypothesis(ops):
    _run_ops(ops, with_cache=True)


def test_shim_exercises_interpreter_when_hypothesis_missing():
    """Guard: if hypothesis is missing the @given suites skip, but the
    fixed-seed streams above must still have run the same interpreter —
    this asserts the interpreter itself is importable and total."""
    h = _run_ops([(0, 0, 0), (1, 0, 3), (2, 0, 1), (3, 0, 1), (5, 2, 0)],
                 with_cache=True)
    assert h.pool.num_blocks == NUM_BLOCKS
    assert HAVE_HYPOTHESIS in (True, False)
