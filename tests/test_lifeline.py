"""Topology + matching properties for the lifeline machinery."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _optional_hypothesis import given, settings, st

from repro.core import GLBParams, lifeline_buddies, lifeline_mask, match_steals


@pytest.mark.parametrize("P,z", [(2, 1), (4, 2), (8, 3), (13, 4), (16, 4), (512, 9)])
def test_buddies_distinct_and_never_self(P, z):
    b = lifeline_buddies(P, z)
    assert b.shape == (P, z)
    for p in range(P):
        assert len(set(b[p])) == z          # distinct buddies
        assert p not in b[p]                # never self


@pytest.mark.parametrize("P,z", [(4, 2), (8, 3), (16, 4), (32, 5)])
def test_lifeline_graph_connected_low_diameter(P, z):
    """Paper §2.4: fully connected directed graph, low diameter, low degree."""
    m = lifeline_mask(P, z)
    assert m.sum(axis=1).max() == z  # out-degree z
    # BFS from every vertex along edges t -> buddy
    import collections

    for s in range(P):
        seen = {s}
        q = collections.deque([(s, 0)])
        diam = 0
        while q:
            u, d = q.popleft()
            diam = max(diam, d)
            for v in np.nonzero(m[u])[0]:
                if v not in seen:
                    seen.add(int(v))
                    q.append((int(v), d + 1))
        assert len(seen) == P, "lifeline graph must be connected"
        assert diam <= 2 * z, "diameter must stay O(log P)"


def _match(P, sizes, pending=None, params=None, seed=0):
    params = params or GLBParams()
    z = params.resolve_z(P)
    buddies = jnp.asarray(lifeline_buddies(P, z))
    sizes = jnp.asarray(sizes, jnp.int32)
    hungry = sizes == 0
    pend = (
        jnp.zeros((P, P), bool) if pending is None else jnp.asarray(pending)
    )
    return match_steals(sizes, hungry, pend, jax.random.key(seed), buddies, params)


@settings(max_examples=40, deadline=None)
@given(
    sizes=st.lists(st.integers(0, 20), min_size=2, max_size=24),
    seed=st.integers(0, 1000),
)
def test_match_is_partial_permutation(sizes, seed):
    P = len(sizes)
    m = _match(P, sizes, seed=seed)
    src = np.asarray(m.src)
    dst = np.asarray(m.dst)
    for t in range(P):
        v = src[t]
        if v >= 0:
            assert sizes[t] == 0, "only hungry places steal"
            assert sizes[v] >= 1, "victims must have work"
            assert dst[v] == t, "src/dst must be consistent"
            assert v != t
    # each victim serves at most one thief
    served = dst[dst >= 0]
    assert len(served) == len(set(served.tolist()))
    matched_thieves = src[src >= 0]
    assert len(np.nonzero(dst >= 0)[0]) == len(matched_thieves)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 1000))
def test_match_thief_with_one_victim_connects(seed):
    # One victim with plenty of work, everyone else starving: with lifelines
    # being a connected graph + random round, at least one thief is served.
    P = 8
    sizes = [0] * P
    sizes[3] = 100
    m = _match(P, sizes, seed=seed)
    assert (np.asarray(m.src) >= 0).sum() == 1
    assert np.asarray(m.dst)[3] >= 0


def test_pending_registration_and_service():
    P = 8
    params = GLBParams(w=0)  # disable random round to isolate lifelines
    # Step 1: everyone starving, nobody can give -> everyone registers
    m1 = _match(P, [0] * P, params=params)
    pend = np.asarray(m1.pending)
    z = params.resolve_z(P)
    assert pend.sum() == P * z
    assert (np.asarray(m1.src) == -1).all()
    # Step 2: place 1 now has work; its pending edges get served
    m2 = _match(P, [0, 50] + [0] * (P - 2), pending=m1.pending, params=params)
    src = np.asarray(m2.src)
    assert (src >= 0).sum() == 1
    t = int(np.nonzero(src >= 0)[0][0])
    assert src[t] == 1
    assert bool(np.asarray(m2.via_lifeline)[t])
    # served thief's pending row is cleared
    assert not np.asarray(m2.pending)[t].any()


def test_no_steal_baseline():
    m = _match(8, [0, 9, 9, 0, 9, 9, 0, 9], params=GLBParams(no_steal=True))
    assert (np.asarray(m.src) == -1).all()
    assert (np.asarray(m.dst) == -1).all()


def test_busy_place_does_not_steal():
    """A place with in-progress state work (hungry=False) must not steal."""
    P = 4
    params = GLBParams()
    z = params.resolve_z(P)
    buddies = jnp.asarray(lifeline_buddies(P, z))
    sizes = jnp.asarray([0, 0, 5, 5], jnp.int32)
    hungry = jnp.asarray([False, True, False, False])  # 0 is busy in-state
    m = match_steals(sizes, hungry, jnp.zeros((P, P), bool),
                     jax.random.key(0), buddies, params)
    assert int(np.asarray(m.src)[0]) == -1
    assert int(np.asarray(m.src)[1]) >= 2
