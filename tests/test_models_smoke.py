"""Per-architecture smoke tests: REDUCED same-family configs, one forward +
one train step + one prefill/decode step on CPU; shape and finiteness
asserts. Full configs are exercised only via the dry-run (no allocation)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import (
    SHAPES, decode_step, forward, init_lm, make_cache, prefill, train_loss,
)

KEY = jax.random.key(0)
B, S = 2, 32


def _smoke_batch(cfg, key):
    ks = jax.random.split(key, 3)
    if cfg.family == "vlm":
        return {
            "embeds": jax.random.normal(ks[0], (B, S, cfg.d_model), jnp.float32),
            "positions": jnp.broadcast_to(
                jnp.arange(S, dtype=jnp.int32)[None, :, None], (B, S, 3)
            ),
            "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab),
        }
    if cfg.n_codebooks:
        return {
            "tokens": jax.random.randint(
                ks[0], (B, S, cfg.n_codebooks), 0, cfg.vocab
            )
        }
    return {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab)}


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_and_train_step(arch):
    cfg = ARCHS[arch].smoke()
    params = init_lm(jax.random.fold_in(KEY, 1), cfg)
    batch = _smoke_batch(cfg, jax.random.fold_in(KEY, 2))

    loss, metrics = jax.jit(
        lambda p, b: train_loss(p, cfg, b)
    )(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    assert float(loss) > 0

    # one SGD step must change the loss and keep it finite (grads flow)
    grads = jax.jit(jax.grad(lambda p, b: train_loss(p, cfg, b)[0]))(
        params, batch
    )
    gnorm = jnp.sqrt(
        sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads))
    )
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0, f"{arch}: bad grads"
    params2 = jax.tree.map(lambda p, g: p - 1e-2 * g, params, grads)
    loss2, _ = jax.jit(lambda p, b: train_loss(p, cfg, b))(params2, batch)
    assert np.isfinite(float(loss2))
    assert float(loss2) != float(loss)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_prefill_decode_consistency(arch):
    """Decode must continue a prefilled cache: logits of position t computed
    via (prefill to t-1, then decode token t) must match a full forward."""
    cfg = ARCHS[arch].smoke()
    if cfg.family == "vlm":
        pytest.skip("vlm decode uses embeds path; covered via qwen2-1.5b twin")
    if cfg.family == "moe":
        # capacity drops legitimately differ between teacher-forced and
        # incremental passes; disable drops for the consistency check
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    params = init_lm(jax.random.fold_in(KEY, 3), cfg)
    tok_shape = (1, S, cfg.n_codebooks) if cfg.n_codebooks else (1, S)
    tokens = jax.random.randint(jax.random.fold_in(KEY, 4), tok_shape, 0, cfg.vocab)

    # full forward (teacher forcing)
    logits_full, _, _ = jax.jit(
        lambda p, t: forward(p, cfg, tokens=t, mode="train")
    )(params, tokens)

    # prefill first S-1 tokens, then decode token S-1
    prompt = tokens[:, : S - 1]
    logits_pre, cache = jax.jit(
        lambda p, t: prefill(params, cfg, {"tokens": t}, max_seq=S - 1)
    )(params, prompt)
    np.testing.assert_allclose(
        np.asarray(logits_pre[:, -1], np.float32),
        np.asarray(logits_full[:, S - 2], np.float32),
        atol=2e-2, rtol=2e-2,
    )

    # decode needs cache sized >= prompt+1: rebuild with slack
    cache2 = make_cache(cfg, 1, S)
    logits_pre2, cache2, _ = jax.jit(
        lambda p, t, c: forward(p, cfg, tokens=t, cache=c,
                                cache_len=jnp.int32(0), mode="prefill")
    )(params, tokens[:, : S - 1]
      if not cfg.n_codebooks else tokens[:, : S - 1], cache2)
    last = tokens[:, S - 1:S]
    logits_dec, _ = jax.jit(
        lambda p, t, c: decode_step(p, cfg, t, c, jnp.int32(S - 1))
    )(params, last, cache2)
    np.testing.assert_allclose(
        np.asarray(logits_dec[:, 0], np.float32),
        np.asarray(logits_full[:, S - 1], np.float32),
        atol=2e-2, rtol=2e-2,
    )


def test_moe_aux_metrics_present():
    cfg = ARCHS["moonshot-v1-16b-a3b"].smoke()
    params = init_lm(KEY, cfg)
    batch = _smoke_batch(cfg, KEY)
    _, metrics = jax.jit(lambda p, b: train_loss(p, cfg, b))(params, batch)
    assert "aux_loss" in metrics and "expert_counts" in metrics
    counts = np.asarray(metrics["expert_counts"])
    assert counts.shape == (cfg.n_experts,)
    # every routed token lands on top_k experts x n_layers
    assert counts.sum() == pytest.approx(B * S * cfg.top_k * cfg.n_layers)


def test_param_counts_sane():
    # full configs: N within 25% of the advertised sizes
    expect = {
        "tinyllama-1.1b": 1.1e9,
        "qwen1.5-110b": 110e9,
        "mistral-nemo-12b": 12e9,
        "mamba2-130m": 130e6,
        "phi3.5-moe-42b-a6.6b": 42e9,
    }
    for name, n in expect.items():
        got = ARCHS[name].param_count()
        assert abs(got - n) / n < 0.25, f"{name}: {got:.3g} vs {n:.3g}"
    # active < total for moe
    moe = ARCHS["phi3.5-moe-42b-a6.6b"]
    assert moe.active_param_count() < moe.param_count() / 3
