"""EP shard_map MoE dispatch == global reference dispatch (subprocess with
8 host devices, mesh (2 data, 4 model))."""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, json
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import AxisType, NamedSharding, PartitionSpec as P
from repro.configs import ARCHS
from repro.models.moe import moe_fwd, moe_init

cfg = dataclasses.replace(
    ARCHS["phi3.5-moe-42b-a6.6b"].smoke(),
    n_experts=8, top_k=2,
    capacity_factor=8.0,  # no drops -> both dispatches exact
)
key = jax.random.key(0)
p = moe_init(key, cfg)
x = jax.random.normal(jax.random.fold_in(key, 1), (4, 16, cfg.d_model),
                      jnp.float32)

mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(AxisType.Auto,) * 2)

cfg_g = dataclasses.replace(cfg, moe_impl="global")
y_ref, aux_ref = moe_fwd(p, x, cfg_g)

cfg_ep = dataclasses.replace(cfg, moe_impl="ep")
fn = jax.jit(lambda p, x: moe_fwd(p, x, cfg_ep))
with jax.sharding.set_mesh(mesh):
    xs = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
    ps = jax.tree.map(lambda a: jax.device_put(
        a, NamedSharding(mesh, P(*([None] * a.ndim)))), p)
    y_ep, aux_ep = fn(ps, xs)

err = float(jnp.abs(y_ref - y_ep).max())
counts_match = bool(np.allclose(np.asarray(aux_ref["expert_counts"]),
                                np.asarray(aux_ep["expert_counts"])))
aux_err = abs(float(aux_ref["aux_loss"]) - float(aux_ep["aux_loss"]))
print("RESULT" + json.dumps({
    "err": err, "counts_match": counts_match, "aux_err": aux_err,
}))
"""


@pytest.mark.slow
def test_ep_dispatch_matches_global():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        env=env, timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][-1]
    out = json.loads(line[len("RESULT"):])
    assert out["err"] < 2e-5, out
    assert out["counts_match"], out
    # aux loss uses the per-DP-shard estimator (mean over shards of
    # fe_local·me_local) — a valid Switch estimator that differs from the
    # global product by O(cross-shard covariance); must be close, not equal
    assert out["aux_err"] < 0.05, out
