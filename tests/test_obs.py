"""Observability layer (DESIGN.md §10): Chrome-trace schema validity and
span nesting under forced preemption+resume and forced migration,
histogram bucket math vs numpy quantiles, NullTracer greedy-token
identity (tracing must not perturb results), stats()/collect() as
registry views, and the traced GLB sim loop matching the jitted one."""
import json

import jax
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core import GLB, GLBParams, fabric_summary, merge_place_stats
from repro.models import init_lm
from repro.obs import (DEFAULT_MS_BUCKETS, NULL_TRACER, Histogram,
                       MetricsRegistry, Tracer, clock_sync,
                       quantiles_from_values, validate_chrome_trace)
from repro.problems.uts import uts_problem
from repro.serve.engine import Engine, GLBReplicaBalancer, Request

CFG = ARCHS["tinyllama-1.1b"].smoke()
PARAMS = init_lm(jax.random.key(0), CFG)

PROMPT16 = [7, 3, 9, 2, 5, 8, 6, 4, 1, 2, 3, 4, 9, 9, 8, 7]
KW = dict(max_slots=2, max_seq=32, pad_len=8, steps_per_sync=8)


def _drive(engine, reqs, guard=500):
    for r in reqs:
        engine.submit(r)
    while engine.load > 0 and guard > 0:
        engine.step()
        guard -= 1
    assert all(r.done for r in reqs)
    return [list(r.out) for r in reqs]


def _req_events(tracer, rid):
    """(ph, name) sequence of one request's async lifecycle events."""
    return [(e["ph"], e["name"]) for e in tracer.events
            if e.get("cat") == "request" and e.get("id") == f"req{rid}"]


# ===================================================== metrics primitives
def test_histogram_quantiles_vs_numpy_fixed_seed():
    """Estimated quantiles land within one covering-bucket width of the
    true sample quantile, on fixed-seed lognormal-ish latency streams."""
    rng = np.random.default_rng(7)
    values = np.exp(rng.normal(1.5, 1.2, size=2000))    # ms scale
    h = Histogram(DEFAULT_MS_BUCKETS)
    for v in values:
        h.observe(v)
    bounds = (0.0,) + tuple(DEFAULT_MS_BUCKETS) + (float(values.max()),)
    for q in (0.1, 0.5, 0.9, 0.99):
        est = h.quantile(q)
        true = float(np.quantile(values, q))
        i = np.searchsorted(bounds, true, side="left")
        width = bounds[min(i, len(bounds) - 1)] - bounds[max(i - 1, 0)]
        assert abs(est - true) <= width + 1e-9, (q, est, true, width)
    assert h.count == 2000
    assert np.isclose(h.total, values.sum())
    assert h.quantile(0.0) >= values.min() - 1e-9
    assert h.quantile(1.0) <= values.max() + 1e-9


def test_histogram_merge_is_exact():
    rng = np.random.default_rng(3)
    a, b = rng.exponential(5.0, 500), rng.exponential(40.0, 300)
    ha, hb, hall = Histogram(), Histogram(), Histogram()
    for v in a:
        ha.observe(v)
        hall.observe(v)
    for v in b:
        hb.observe(v)
        hall.observe(v)
    ha.merge_from(hb)
    assert ha.counts == hall.counts
    assert ha.count == hall.count == 800
    assert np.isclose(ha.total, hall.total)
    assert ha.quantile(0.5) == hall.quantile(0.5)


def test_histogram_bounds_validation_raises():
    """User-input validation must be real exceptions, not asserts —
    asserts vanish under `python -O` and a silently-accepted bad bucket
    layout corrupts every merge downstream."""
    with pytest.raises(ValueError):
        Histogram(())
    with pytest.raises(ValueError):
        Histogram((1.0, 1.0, 2.0))          # not strictly ascending
    with pytest.raises(ValueError):
        Histogram((5.0, 1.0))


def test_histogram_merge_mismatched_bounds_raises():
    """Regression: merging histograms with different bucket layouts is a
    ValueError (the counts would be meaningless bucket-for-bucket)."""
    a = Histogram((1.0, 2.0, 4.0))
    b = Histogram((1.0, 2.0, 8.0))
    a.observe(1.5)
    b.observe(3.0)
    with pytest.raises(ValueError, match="different bucket layouts"):
        a.merge_from(b)
    # the failed merge must not have corrupted the target
    assert a.count == 1 and a.counts == [0, 1, 0, 0]


def test_quantiles_from_values_matches_histogram():
    vals = [1.0, 2.0, 4.0, 8.0, 100.0]
    h = Histogram()
    for v in vals:
        h.observe(v)
    assert quantiles_from_values(vals, [0.5, 0.99]) == [h.quantile(0.5),
                                                        h.quantile(0.99)]


def test_registry_merge_and_kind_uniqueness():
    r1, r2 = MetricsRegistry(), MetricsRegistry()
    r1.counter("reqs").inc(3)
    r2.counter("reqs").inc(4)
    r1.gauge("peak").set(5)
    r2.gauge("peak").set(9)
    r1.histogram("lat_ms").observe(2.0)
    r2.histogram("lat_ms").observe(200.0)
    m = MetricsRegistry.merged([r1, r2])
    snap = m.snapshot()
    assert snap["reqs"] == 7.0            # counters add
    assert snap["peak"] == 9.0            # gauges keep the high-water mark
    assert snap["lat_ms_count"] == 2.0    # histograms merge buckets
    with pytest.raises(ValueError):
        r1.gauge("reqs")                  # name already a counter
    text = m.render_prometheus()
    assert "# TYPE repro_reqs counter" in text
    assert 'repro_lat_ms_bucket{le="+Inf"} 2' in text
    assert text.endswith("\n")


# ================================================== tracer schema contract
def test_chrome_trace_schema_and_flush_balance():
    tr = Tracer()
    tr.begin("outer", pid=1)
    tr.begin("inner", pid=1)
    tr.end(pid=1)
    tr.instant("tick", pid=1)
    tr.counter("load", {"q": 3}, pid=1)
    tr.req_begin(7, pid=1)
    tr.req_phase(7, "queued", pid=1)
    tr.req_phase(7, "decode", pid=2)      # phase ownership moves pids
    # "outer" and req 7's decode phase left open: flush must close both.
    tr.flush()
    trace = tr.to_chrome()
    assert validate_chrome_trace(trace) == []
    assert trace["displayTimeUnit"] == "ms"
    assert "clock_sync" in trace["otherData"]
    # the closing "e" of a phase is stamped with the OPENING pid
    evs = _req_events(tr, 7)
    assert ("e", "queued") in evs
    queued_end = next(e for e in tr.events if e.get("ph") == "e"
                      and e.get("name") == "queued")
    assert queued_end["pid"] == 1
    json.dumps(trace)                     # serializable as-is


def test_tracer_write_is_atomic(tmp_path):
    """write() lands via temp-file + os.replace: the previous complete
    file survives any interruption, no temp litter remains, and the
    written JSON round-trips through the validator."""
    path = tmp_path / "trace.json"
    path.write_text('{"traceEvents": "PREVIOUS COMPLETE FILE"}')
    tr = Tracer()
    tr.begin("outer", pid=0)
    tr.req_begin(1, pid=0)
    tr.req_phase(1, "queued", pid=0)
    tr.write(str(path))
    loaded = json.loads(path.read_text())
    assert validate_chrome_trace(loaded) == []
    assert not list(tmp_path.glob(".trace.*")), "temp file left behind"
    # a failing serialization must not clobber the existing file
    tr2 = Tracer()
    tr2.events.append({"ph": "i", "name": "bad", "ts": 1, "pid": 0,
                       "tid": 0, "args": {"x": object()}})
    with pytest.raises(TypeError):
        tr2.write(str(path))
    assert json.loads(path.read_text()) == loaded
    assert not list(tmp_path.glob(".trace.*"))


def test_tracer_dump_is_non_destructive():
    """dump() exports a balanced copy of a LIVE tracer: open spans and
    phases are closed in the export only, and tracing continues."""
    tr = Tracer()
    tr.begin("outer", pid=0)
    tr.req_begin(3, pid=0)
    tr.req_phase(3, "decode", pid=0)
    n_before = len(tr.events)
    dump = tr.dump()
    assert validate_chrome_trace(dump) == []
    assert len(tr.events) == n_before           # tracer untouched
    assert tr._stacks[(0, 0)] == ["outer"]      # still open
    tr.end(pid=0)                               # still usable
    tr.req_end(3, pid=0)
    tr.flush()
    assert validate_chrome_trace(tr.to_chrome()) == []


def test_validator_catches_malformed_traces():
    bad = {"traceEvents": [{"ph": "E", "ts": 1, "pid": 0, "tid": 0},
                           {"ph": "B", "ts": 2, "pid": 0, "tid": 0,
                            "name": "x"},
                           {"ph": "b", "ts": 3, "pid": 0, "tid": 0,
                            "name": "y", "cat": "request"},
                           {"ts": 4, "pid": 0, "tid": 0}]}
    problems = validate_chrome_trace(bad)
    assert any("E without open B" in p for p in problems)
    assert any("unclosed duration" in p for p in problems)
    assert any("missing id" in p for p in problems)
    assert any("missing 'ph'" in p for p in problems)
    assert validate_chrome_trace({}) == ["traceEvents missing or not a list"]


def test_clock_sync_anchors_agree():
    s1, s2 = clock_sync(), clock_sync()
    u1 = s1["unix_ts"] - s1["perf_us"] / 1e6
    u2 = s2["unix_ts"] - s2["perf_us"] / 1e6
    assert abs(u1 - u2) < 0.5             # same clock-domain offset


# ================================================ lifecycle: preempt/resume
def test_preemption_resume_span_ordering():
    """A pool too small for both sequences forces watermark preemption;
    the preempted request's lifecycle must read
    queued -> prefill -> decode -> preempted -> queued -> ... -> resumed
    -> decode -> end, and the full trace must validate."""
    tr = Tracer()
    e = Engine(CFG, PARAMS, paged=True, block_size=8, num_blocks=5,
               tracer=tr, **KW)
    reqs = [Request(rid=i, prompt=[3, i + 1, 4, 2], max_new=14 + i % 4)
            for i in range(5)]
    _drive(e, reqs)
    assert e.sched.preemptions > 0, "pool sizing must force preemption"
    tr.flush()
    assert validate_chrome_trace(tr.to_chrome()) == []
    preempted_rids = [ev.get("id") for ev in tr.events
                      if ev.get("ph") == "n" and ev["name"] == "preempted"]
    assert preempted_rids
    rid = int(preempted_rids[0][len("req"):])
    evs = _req_events(tr, rid)
    # begins/ends balanced and the request span closed exactly once
    assert evs[0] == ("b", "request") and evs[-1] == ("e", "request")
    assert evs.count(("e", "request")) == 1
    # preempted -> back to queued -> eventually resumed -> decode again
    i_pre = evs.index(("n", "preempted"))
    assert ("b", "decode") in evs[:i_pre]
    # the transition closes the open phase first, then re-opens queued
    assert ("b", "queued") in evs[i_pre + 1:i_pre + 3]
    i_res = evs.index(("n", "resumed"))
    assert i_res > i_pre
    assert ("b", "decode") in evs[i_res:]
    # metrics observed at request boundaries
    snap = e.stats()
    assert snap["ttft_ms_count"] == len(reqs)
    assert snap["tpot_ms_count"] == len(reqs)
    assert snap["queue_wait_ms_count"] >= len(reqs) + 1  # re-queued waits
    assert snap["preemptions"] == e.sched.preemptions


# =================================================== lifecycle: migration
def test_migration_span_ownership_across_replicas():
    """Forced live migration: the victim opens the migrate phase, the
    thief closes it — one shared tracer keeps the request's async span
    chain valid across both pids."""
    tr = Tracer()
    kw = dict(max_slots=1, max_seq=64, pad_len=16, steps_per_sync=4)
    victim = Engine(CFG, PARAMS, paged=True, block_size=8, tracer=tr,
                    replica_id=0, **kw)
    thief = Engine(CFG, PARAMS, paged=True, block_size=8, tracer=tr,
                   replica_id=1, **kw)
    req = Request(rid=0, prompt=list(PROMPT16), max_new=30)
    victim.submit(req)
    for _ in range(7):
        victim.step()
    assert not req.done
    mode = thief.migrate_in(victim.migrate_out(0))
    assert mode == "live"
    guard = 200
    while thief.load > 0 and guard > 0:
        thief.step()
        guard -= 1
    assert req.done
    tr.flush()
    assert validate_chrome_trace(tr.to_chrome()) == []
    evs = _req_events(tr, 0)
    i_out = evs.index(("n", "migrated_out"))
    assert ("b", "migrate") in evs[i_out:]
    i_in = evs.index(("n", "migrated_in"))
    assert i_in > i_out
    assert evs[-1] == ("e", "request")
    # the migrate phase was opened on pid 0 and closed by pid 0's stamp
    # when pid 1 transitioned the request to decode
    mig_b = next(ev for ev in tr.events if ev.get("ph") == "b"
                 and ev["name"] == "migrate")
    assert mig_b["pid"] == 0
    dec_after = [ev for ev in tr.events if ev.get("ph") == "b"
                 and ev["name"] == "decode" and ev["ts"] > mig_b["ts"]]
    assert dec_after and dec_after[-1]["pid"] == 1
    # migration payload metrics observed on both ends
    assert victim.stats()["migrate_pack_ms_count"] == 1
    assert victim.stats()["migration_bytes_count"] == 1
    assert victim.stats()["migration_bytes_sum"] > 0
    assert thief.stats()["migrate_land_ms_count"] == 1
    # TTFT was stamped on the victim; the thief reports the finish
    assert thief.stats()["requests_finished"] == 1


# ======================================================= identity & stats
def test_nulltracer_and_tracer_token_identity():
    """Tracing must not perturb scheduling or sampling: untraced (the
    NullTracer default), and fully traced runs of the same workload emit
    identical greedy tokens."""
    def outs(tracer):
        e = Engine(CFG, PARAMS, paged=True, block_size=8, num_blocks=5,
                   prefix_cache=True, prefill_chunk=4, tracer=tracer,
                   **KW)
        return _drive(e, [Request(rid=i, prompt=[3, i + 1, 4, 2],
                                  max_new=14 + i % 4) for i in range(5)])

    assert Engine(CFG, PARAMS, **KW).tracer is NULL_TRACER
    assert outs(None) == outs(Tracer())


def test_stats_is_registry_view_and_merge_superset():
    """Engine.stats() == metrics snapshot; merged fabric keys are a
    superset of every per-replica snapshot's keys (the satellite
    regression: no more hand-rolled drift between the three report
    sites)."""
    engines = [Engine(CFG, PARAMS, paged=True, block_size=8,
                      prefix_cache=True, replica_id=i, **KW)
               for i in range(2)]
    bal = GLBReplicaBalancer(engines, migrate=True)
    for i in range(6):
        bal.submit(Request(rid=i, prompt=[3, i + 1, 4, 2], max_new=8))
    bal.run(max_steps=300)
    snaps = [e.stats() for e in engines]
    for e, snap in zip(engines, snaps):
        assert snap == e.metrics.snapshot()
        assert snap["prefix_hit_rate_pct"] == round(
            100 * e.prefix_cache.hit_rate, 1)
    merged = bal.collect()
    for snap in snaps:
        assert set(merged) >= set(snap), set(snap) - set(merged)
    # fabric_summary accepts the pre-merged registry view directly
    text = fabric_summary(merged, title="replica fabric", places=2)
    assert text.splitlines()[0] == "replica fabric: 2 places"
    assert "ttft_ms_p99" in text
    assert fabric_summary(snaps, title="replica fabric") .splitlines()[0] \
        == "replica fabric: 2 places"
    # merged registry: histogram quantiles of the merged distribution
    msnap = bal.merged_metrics().snapshot()
    assert msnap["ttft_ms_count"] == merge_place_stats(snaps)[
        "ttft_ms_count"]["total"]


# ===================================================== GLB core sim tracing
def test_run_sim_traced_matches_untraced():
    prob = uts_problem(depth=4)
    g1 = GLB(prob, GLBParams(n=8), P=4)
    r1 = g1.run(seed=0)
    tr = Tracer()
    g2 = GLB(prob, GLBParams(n=8), P=4)
    r2 = g2.run(seed=0, tracer=tr)
    assert int(np.asarray(r1)) == int(np.asarray(r2))
    assert g1.supersteps == g2.supersteps
    for f in g1.stats:
        assert np.array_equal(np.asarray(g1.stats[f]),
                              np.asarray(g2.stats[f])), f
    tr.flush()
    assert validate_chrome_trace(tr.to_chrome()) == []
    spans = [e for e in tr.events if e.get("ph") == "B"
             and e["name"] == "superstep"]
    assert len(spans) == g2.supersteps
    loads = [e for e in tr.events if e.get("ph") == "C"
             and e["name"] == "glb_load"]
    assert loads and loads[-1]["args"]["total"] == 0.0
