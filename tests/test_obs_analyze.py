"""Trace analytics, flight recorder, and SLO monitor (DESIGN.md §14):
attribution completeness over randomized synthetic request lifecycles
(property test + fixed-seed fallback), flight-ring wraparound producing
validator-clean dumps at every capacity, analyzer results over real
engine runs (preemption buckets, cross-replica migration stitches,
steal efficiency), burn-rate alert state transitions, and the
``python -m repro.obs.analyze`` CLI gate."""
import json
import subprocess
import sys

import jax
import numpy as np
import pytest

from tests._optional_hypothesis import HAVE_HYPOTHESIS, given, settings, st

from repro.configs import ARCHS
from repro.models import init_lm
from repro.obs import (FlightRecorder, MetricsRegistry, SLOMonitor,
                       SLOTarget, Tracer, analyze_trace, check_invariants,
                       parse_slo_spec, render_markdown, render_summary,
                       validate_chrome_trace)
from repro.obs.analyze import BUCKETS, headline, main as analyze_main
from repro.serve.engine import Engine, GLBReplicaBalancer, Request

CFG = ARCHS["tinyllama-1.1b"].smoke()
PARAMS = init_lm(jax.random.key(0), CFG)

PROMPT16 = [7, 3, 9, 2, 5, 8, 6, 4, 1, 2, 3, 4, 9, 9, 8, 7]


# ===================================================== synthetic lifecycles
class FakeClock:
    """Deterministic now_us(): each call returns the scripted time, so a
    synthetic lifecycle's phase transitions are atomic (both the close
    and the open of a transition read the SAME tick) and bucket sums
    equal wall-clock exactly."""

    def __init__(self, t0=1_000.0):
        self.t = t0

    def __call__(self):
        return self.t

    def tick(self, dt):
        self.t += dt
        return self.t


@pytest.fixture()
def clock(monkeypatch):
    clk = FakeClock()
    monkeypatch.setattr("repro.obs.trace.now_us", clk)
    return clk


def _run_lifecycle(tr, clk, rid, ops):
    """Drive one request through the REAL tracer API from an op list of
    (action, dwell_us) pairs; returns the expected bucket sums."""
    expect = {b: 0.0 for b in BUCKETS}
    tr.req_begin(rid, pid=0)
    tr.req_phase(rid, "queued", pid=0)
    cur, pid = "queued", 0
    preempted = False
    for action, dwell in ops:
        clk.tick(dwell)
        if cur == "queued":
            expect["preempted" if preempted else "queued"] += dwell
            preempted = False
        elif cur == "migrate":
            expect["migrating"] += dwell
        else:
            expect[cur] += dwell
        if action == "prefill":
            tr.req_phase(rid, "prefill", pid=pid)
            cur = "prefill"
        elif action == "decode":
            tr.req_phase(rid, "decode", pid=pid)
            cur = "decode"
        elif action == "preempt":
            tr.req_instant(rid, "preempted", pid=pid)
            tr.req_phase(rid, "queued", pid=pid)
            cur, preempted = "queued", True
        elif action == "migrate":
            tr.req_instant(rid, "migrated_out", pid=pid,
                           args={"bytes": 2048})
            tr.req_phase(rid, "migrate", pid=pid)
            cur, pid = "migrate", pid + 1
        elif action == "land":
            tr.req_instant(rid, "migrated_in", pid=pid)
            tr.req_phase(rid, "decode", pid=pid)
            cur = "decode"
    clk.tick(10.0)
    if cur == "queued":
        expect["preempted" if preempted else "queued"] += 10.0
    elif cur == "migrate":
        expect["migrating"] += 10.0
    else:
        expect[cur] += 10.0
    tr.req_end(rid, pid=pid)
    return expect


def _random_ops(rng, n):
    """Random legal op sequence: prefill -> decode, then any mix of
    preempt->prefill->decode cycles and migrate->land hops."""
    ops = [("prefill", float(rng.integers(1, 500))),
           ("decode", float(rng.integers(1, 500)))]
    for _ in range(n):
        r = rng.random()
        if r < 0.4:
            ops.append(("preempt", float(rng.integers(1, 500))))
            ops.append(("prefill", float(rng.integers(1, 500))))
            ops.append(("decode", float(rng.integers(1, 500))))
        elif r < 0.7:
            ops.append(("migrate", float(rng.integers(1, 500))))
            ops.append(("land", float(rng.integers(1, 200))))
        else:
            ops.append(("decode", float(rng.integers(1, 500))))
    return ops


def _check_attribution(tr, clk, n_reqs, rng):
    expects = {}
    for rid in range(n_reqs):
        expects[rid] = _run_lifecycle(tr, clk, rid,
                                      _random_ops(rng,
                                                  int(rng.integers(0, 6))))
    a = analyze_trace(tr)
    assert a.validator_problems == []
    assert check_invariants(a, max_unattributed=0.01,
                            abs_slack_us=1e-6) == []
    for rid, expect in expects.items():
        r = a.request(rid)
        assert r is not None
        wall = sum(expect.values())
        assert abs(r.wall_us - wall) < 1e-6
        for b in BUCKETS:
            assert abs(r.buckets[b] - expect[b]) < 1e-6, (
                rid, b, r.buckets, expect)
        # exhaustive under the fake clock: transitions are atomic
        assert abs(r.unattributed_us) < 1e-6


def test_attribution_exhaustive_fixed_seeds(clock):
    """Fixed-seed fallback for the property test below: ~25 randomized
    multi-request lifecycle tapes, buckets must equal wall-clock
    exactly under the fake clock (runs with or without hypothesis)."""
    for seed in range(25):
        tr = Tracer()
        _check_attribution(tr, clock, n_reqs=3,
                           rng=np.random.default_rng(seed))


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@given(st.integers(min_value=0, max_value=2**32 - 1))
@settings(max_examples=50, deadline=None)
def test_attribution_exhaustive_property(seed):
    """Property form: any legal preempt/resume/migrate sequence is
    attributed exhaustively (no fixture — hypothesis reuses the test)."""
    clk = FakeClock()
    import repro.obs.trace as trace_mod
    orig = trace_mod.now_us
    trace_mod.now_us = clk
    try:
        tr = Tracer()
        _check_attribution(tr, clk, n_reqs=2,
                           rng=np.random.default_rng(seed))
    finally:
        trace_mod.now_us = orig


def test_preempted_bucket_distinct_from_arrival_queueing(clock):
    """Arrival queueing and post-preemption requeue time land in
    different buckets even though both are 'queued' phases."""
    tr = Tracer()
    tr.req_begin(0, pid=0)
    tr.req_phase(0, "queued", pid=0)
    clock.tick(100.0)
    tr.req_phase(0, "prefill", pid=0)
    clock.tick(50.0)
    tr.req_phase(0, "decode", pid=0)
    clock.tick(200.0)
    tr.req_instant(0, "preempted", pid=0)
    tr.req_phase(0, "queued", pid=0)
    clock.tick(70.0)
    tr.req_instant(0, "resumed", pid=0)
    tr.req_phase(0, "decode", pid=0)
    clock.tick(30.0)
    tr.req_end(0, pid=0)
    r = analyze_trace(tr).request(0)
    assert r.buckets["queued"] == pytest.approx(100.0)
    assert r.buckets["preempted"] == pytest.approx(70.0)
    assert r.buckets["decode"] == pytest.approx(230.0)
    assert r.preemptions == 1
    assert r.unattributed_us == pytest.approx(0.0, abs=1e-9)


# ======================================================== flight recorder
def _emit_workload(tr):
    """Mixed-vocabulary workload: nested duration spans, async request
    lifecycles with preemption + migration, instants, counters, and
    still-open spans at dump time."""
    tr.process_name(0, "replica 0")
    tr.process_name(1, "replica 1")
    tr.thread_name(0, 0, "engine")
    for rid in range(4):
        tr.req_begin(rid, pid=0)
        tr.req_phase(rid, "queued", pid=0)
    for step in range(8):
        tr.begin("engine_step", pid=0)
        tr.begin("prefill", pid=0)
        tr.end(pid=0)
        tr.end(pid=0)
        tr.counter("load", {"running": float(step)}, pid=0)
    tr.req_phase(0, "prefill", pid=0)
    tr.req_phase(0, "decode", pid=0)
    tr.req_instant(1, "preempted", pid=0)
    tr.req_phase(1, "queued", pid=0)
    tr.req_instant(0, "migrated_out", pid=0, args={"bytes": 4096})
    tr.req_phase(0, "migrate", pid=0)
    tr.req_instant(0, "migrated_in", pid=1)
    tr.req_phase(0, "decode", pid=1)
    tr.req_end(0, pid=1, args={"tokens": 9})
    tr.req_end(1, pid=0)
    tr.instant("steal_queued", pid=2, args={"n": 2})
    tr.begin("superstep", pid=2)        # left open at dump time


@pytest.mark.parametrize("capacity",
                         [1, 2, 3, 5, 8, 13, 21, 40, 64, 128, 999, 5000])
def test_flight_dump_valid_at_every_capacity(capacity):
    """The ISSUE acceptance criterion: a wrapped (or not) ring ALWAYS
    dumps a balanced, validator-clean trace."""
    fr = FlightRecorder(capacity=capacity)
    _emit_workload(fr)
    dump = fr.dump()
    assert validate_chrome_trace(dump) == []
    fl = dump["otherData"]["flight"]
    assert fl["capacity"] == capacity
    assert len(fr.events) <= capacity


def test_flight_drop_count_matches_plain_tracer():
    plain = Tracer()
    _emit_workload(plain)
    ring_eligible = sum(1 for e in plain.events if e.get("ph") != "M")
    for capacity in (1, 7, 33, 1000):
        fr = FlightRecorder(capacity=capacity)
        _emit_workload(fr)
        assert fr.dropped == max(0, ring_eligible - capacity)


def test_flight_ample_capacity_drops_and_synthesizes_nothing():
    fr = FlightRecorder(capacity=100_000)
    _emit_workload(fr)
    dump = fr.dump()
    assert dump["otherData"]["flight"]["dropped"] == 0
    assert dump["otherData"]["flight"]["synthesized_opens"] == 0
    assert validate_chrome_trace(dump) == []


def test_flight_dump_is_non_destructive():
    fr = FlightRecorder(capacity=64)
    _emit_workload(fr)
    a = fr.dump()
    b = fr.dump()
    assert len(a["traceEvents"]) == len(b["traceEvents"])
    assert validate_chrome_trace(b) == []
    fr.begin("more", pid=0)             # still recording after dumps
    fr.end(pid=0)
    assert validate_chrome_trace(fr.dump()) == []


def test_flight_capacity_validation():
    with pytest.raises(ValueError):
        FlightRecorder(capacity=0)
    with pytest.raises(ValueError):
        FlightRecorder(capacity=-5)


def test_flight_write_is_atomic_and_valid(tmp_path):
    fr = FlightRecorder(capacity=16)
    _emit_workload(fr)
    path = tmp_path / "flight.json"
    fr.write(str(path))
    loaded = json.loads(path.read_text())
    assert validate_chrome_trace(loaded) == []
    assert loaded["otherData"]["flight"]["dropped"] == fr.dropped
    assert not list(tmp_path.glob(".trace.*"))  # no temp litter


def test_flight_truncated_requests_flagged_not_gated():
    """Requests whose begin fell off the ring are marked truncated and
    exempt from the attribution invariant (their history is a suffix)."""
    fr = FlightRecorder(capacity=8)
    _emit_workload(fr)
    a = analyze_trace(fr)
    assert a.validator_problems == []
    assert any(r.truncated for r in a.requests)
    assert check_invariants(a) == []


# ================================================== analyzer, real engine
def test_analyzer_real_engine_preemption():
    """Block-starved paged engine: preemptions happen, and the analyzer
    attributes >=99% of every request's wall-clock with a nonzero
    preempted bucket."""
    tr = Tracer()
    eng = Engine(CFG, PARAMS, paged=True, block_size=8, num_blocks=5,
                 max_slots=2, max_seq=32, pad_len=8, steps_per_sync=8,
                 tracer=tr)
    reqs = [Request(rid=i, prompt=[3, i + 1, 4, 2], max_new=14 + i % 4)
            for i in range(5)]
    for r in reqs:
        eng.submit(r)
    guard = 500
    while eng.load > 0 and guard > 0:
        eng.step()
        guard -= 1
    assert eng.sched.preemptions > 0
    a = analyze_trace(tr)
    assert a.validator_problems == []
    assert check_invariants(a, max_unattributed=0.01) == []
    assert len(a.requests) == 5
    for r in a.requests:
        assert r.unattributed_frac <= 0.01
    assert a.bucket_totals()["preempted"] > 0
    assert sum(r.preemptions for r in a.requests) == eng.sched.preemptions
    rep = a.replicas[0]
    assert rep.steps == eng.steps
    assert rep.busy_us > 0 and rep.utilization > 0
    # reports render without error and carry the headline facts
    md = render_markdown(a)
    assert "Request time attribution" in md and "preempted" in md
    assert "p99" in render_summary(a) or "request" in render_summary(a)
    assert "analysis:" in headline(a)


def test_analyzer_real_engine_migration_stitch():
    """Live migration: the analyzer stitches the request across pids,
    reports the migrating bucket, migration bytes, and post-migration
    decode time (steal-efficiency numerator)."""
    tr = Tracer()
    kw = dict(max_slots=1, max_seq=64, pad_len=16, steps_per_sync=4)
    victim = Engine(CFG, PARAMS, paged=True, block_size=8, tracer=tr,
                    replica_id=0, **kw)
    thief = Engine(CFG, PARAMS, paged=True, block_size=8, tracer=tr,
                   replica_id=1, **kw)
    req = Request(rid=0, prompt=list(PROMPT16), max_new=30)
    victim.submit(req)
    for _ in range(7):
        victim.step()
    assert thief.migrate_in(victim.migrate_out(0)) == "live"
    guard = 200
    while thief.load > 0 and guard > 0:
        thief.step()
        guard -= 1
    a = analyze_trace(tr)
    assert a.validator_problems == []
    assert check_invariants(a) == []
    r = a.request(0)
    assert r.replicas == [0, 1]
    assert r.migrations == 1
    assert r.migration_bytes > 0
    assert r.buckets["migrating"] > 0
    assert r.post_migration_decode_us > 0
    assert r.unattributed_frac <= 0.01
    assert {rep.pid for rep in a.replicas} == {0, 1}
    s = a.steal
    assert s.migration_bytes == r.migration_bytes
    assert s.moved_decode_us == pytest.approx(r.post_migration_decode_us)
    assert s.moved_decode_us_per_kib > 0


def test_analyzer_fabric_steal_efficiency():
    """Balancer-driven fabric: steal instants inside superstep spans
    count as steal rounds; tier-1 moves come from the instants' n."""
    tr = Tracer()
    engines = [Engine(CFG, PARAMS, paged=True, block_size=8, max_slots=2,
                      max_seq=32, pad_len=8, steps_per_sync=4, tracer=tr,
                      replica_id=i) for i in range(2)]
    bal = GLBReplicaBalancer(engines, migrate=True, tracer=tr)
    for i in range(6):
        engines[0].submit(Request(rid=i, prompt=[3, i + 1, 4, 2],
                                  max_new=8))
    bal.run(max_steps=200)
    assert bal.terminated
    a = analyze_trace(tr)
    assert a.validator_problems == []
    assert check_invariants(a) == []
    assert a.steal.supersteps == bal.supersteps + 1  # + the final pass
    assert a.steal.tier1_moves + a.steal.tier2_moves == bal.moves
    if bal.moves:
        assert a.steal.steal_rounds > 0
        assert a.steal.moves_per_steal_round > 0


def test_analyze_cli_gate(tmp_path):
    """The CLI is the CI gate: exit 0 + report files on a good trace,
    exit 1 on a corrupted one."""
    tr = Tracer()
    eng = Engine(CFG, PARAMS, max_slots=2, max_seq=32, pad_len=8,
                 steps_per_sync=8, tracer=tr)
    for i in range(3):
        eng.submit(Request(rid=i, prompt=[3, i + 1, 4], max_new=6))
    guard = 200
    while eng.load > 0 and guard > 0:
        eng.step()
        guard -= 1
    trace_path = tmp_path / "trace.json"
    tr.write(str(trace_path))
    out_md = tmp_path / "report.md"
    summary = tmp_path / "summary.md"
    rc = analyze_main([str(trace_path), "--out", str(out_md),
                       "--summary", str(summary)])
    assert rc == 0
    assert "Request time attribution" in out_md.read_text()
    assert summary.read_text().startswith("# Trace analysis")
    rc_json = analyze_main([str(trace_path), "--json"])
    assert rc_json == 0
    # corrupt the trace: drop an async close -> validator + gate fail
    trace = json.loads(trace_path.read_text())
    victim_i = next(i for i, e in enumerate(trace["traceEvents"])
                    if e.get("ph") == "e")
    del trace["traceEvents"][victim_i]
    bad_path = tmp_path / "bad.json"
    bad_path.write_text(json.dumps(trace))
    assert analyze_main([str(bad_path)]) == 1


def test_analyze_cli_subprocess_entrypoint(tmp_path):
    """`python -m repro.obs.analyze` (the exact CI invocation) works."""
    tr = Tracer()
    tr.req_begin(0, pid=0)
    tr.req_phase(0, "queued", pid=0)
    tr.req_phase(0, "decode", pid=0)
    tr.req_end(0, pid=0)
    path = tmp_path / "t.json"
    tr.write(str(path))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.obs.analyze", str(path)],
        capture_output=True, text=True, env={"PYTHONPATH": "src",
                                             "PATH": "/usr/bin:/bin"},
        cwd="/root/repo")
    assert proc.returncode == 0, proc.stderr
    assert "Trace analysis" in proc.stdout


# ====================================================== slo monitor
def test_slo_parse_spec():
    targets = parse_slo_spec("ttft_ms=250,tpot_ms=50@0.999")
    assert targets[0] == SLOTarget("ttft_ms", 250.0, 0.99)
    assert targets[1] == SLOTarget("tpot_ms", 50.0, 0.999)
    for bad in ("ttft", "x=0", "x=5@1.5", "x=5@0"):
        with pytest.raises(ValueError):
            parse_slo_spec(bad)


def test_slo_validation():
    with pytest.raises(ValueError):
        SLOMonitor([])
    with pytest.raises(ValueError):
        SLOMonitor([SLOTarget("a", 1.0), SLOTarget("a", 2.0)])
    with pytest.raises(ValueError):
        SLOMonitor([SLOTarget("a", 1.0)], windows=((5.0, 60.0, 10.0),))
    with pytest.raises(ValueError):
        SLOMonitor([SLOTarget("a", 1.0)], windows=((60.0, 5.0, 0.5),))


def test_slo_burn_alert_transitions():
    """Multi-window burn alerting: healthy stream -> no alert; sustained
    50% violation rate -> ONE alert instant; recovery -> one clear."""
    tr = Tracer()
    reg = MetricsRegistry()
    m = SLOMonitor([SLOTarget("ttft_ms", 100.0, 0.99)],
                   windows=((60.0, 5.0, 10.0),), tracer=tr, metrics=reg,
                   pid=9)
    t0 = 1e6
    for i in range(100):
        m.observe("ttft_ms", 10.0, ts_us=t0 + i * 1e4)
    assert m.check(ts_us=t0 + 1e6) == []
    for i in range(100):
        m.observe("ttft_ms", 500.0 if i % 2 else 10.0,
                  ts_us=t0 + 2e6 + i * 1e4)
    assert m.check(ts_us=t0 + 3e6) == ["ttft_ms"]
    assert m.check(ts_us=t0 + 3.1e6) == ["ttft_ms"]   # sustained: 1 alert
    assert m.alerts_fired == 1
    for i in range(600):
        m.observe("ttft_ms", 10.0, ts_us=t0 + 4e6 + i * 1e4)
    assert m.check(ts_us=t0 + 10e6) == []
    names = [e["name"] for e in tr.events if e.get("ph") == "i"]
    assert names == ["slo_burn", "slo_burn_clear"]
    burn = next(e for e in tr.events if e.get("name") == "slo_burn")
    assert burn["pid"] == 9
    assert burn["args"]["metric"] == "ttft_ms"
    snap = reg.snapshot()
    assert snap["slo_burn_alerts"] == 1.0
    assert snap["slo_ttft_ms_violations"] == 50.0
    assert m.attainment()["ttft_ms"]["attained"] == pytest.approx(750 / 800)


def test_slo_single_window_no_flap():
    """A short burst that clears before the long window fills must NOT
    alert (the long window is the flap damper)."""
    m = SLOMonitor([SLOTarget("ttft_ms", 100.0, 0.99)],
                   windows=((60.0, 5.0, 10.0),))
    t0 = 1e6
    for i in range(1000):
        m.observe("ttft_ms", 10.0, ts_us=t0 + i * 1e4)
    # 3 bad samples right at the end: short-window burn spikes, long
    # window stays healthy
    for i in range(3):
        m.observe("ttft_ms", 500.0, ts_us=t0 + 1e7 + i * 1e3)
    assert m.check(ts_us=t0 + 1e7 + 3e3) == []
    assert m.alerts_fired == 0


def test_slo_ignores_undeclared_metrics():
    m = SLOMonitor([SLOTarget("ttft_ms", 100.0)])
    m.observe("tpot_ms", 1e9, ts_us=1.0)     # no target: ignored
    assert m.attainment().keys() == {"ttft_ms"}


def test_slo_engine_integration():
    """Engine + balancer wiring: slo= threads to every engine and its
    scheduler, observations flow, collect() grows _slo, report() states
    attainment, and fabric_summary skips the _slo sub-dict."""
    from repro.core import fabric_summary
    slo = SLOMonitor([SLOTarget("ttft_ms", 0.001),    # unmeetable
                      SLOTarget("tpot_ms", 1e6)])     # unmissable
    engines = [Engine(CFG, PARAMS, paged=True, block_size=8, max_slots=2,
                      max_seq=32, pad_len=8, steps_per_sync=4,
                      replica_id=i) for i in range(2)]
    bal = GLBReplicaBalancer(engines, slo=slo)
    assert all(e.slo is slo for e in engines)
    assert all(e.sched.slo is slo for e in engines)
    assert slo.pid == bal._fabric_pid
    for i in range(4):
        engines[0].submit(Request(rid=i, prompt=[3, i + 1, 4, 2],
                                  max_new=6))
    bal.run(max_steps=200)
    col = bal.collect()
    assert col["_slo"]["slo_ttft_ms_violations"] == 4.0
    assert col["_slo"]["slo_ttft_ms_met"] == 0.0
    assert col["_slo"]["slo_tpot_ms_met"] == 1.0
    report = bal.report()
    assert "slo ttft_ms" in report and "[MISSED]" in report
    assert "slo tpot_ms" in report and "[MET]" in report
    fabric_summary(col)                  # _-prefixed sub-dicts skipped
