"""Paged decode alignment contract: the block-table-walking Pallas kernel
(interpret mode) and the gather oracle must match ref.attention_ref on
each sequence's logically-ordered visible window, across scrambled block
tables, garbage entries past the allocation, head layouts (MHA/GQA), and
idle slots."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.paged_decode import paged_decode

KEY = jax.random.key(11)
BS = 16          # pool block size (tokens)
MAX_BLOCKS = 8   # logical blocks per sequence (max_seq = 128)
NUM_BLOCKS = 40  # physical pool blocks


def _pool(B, Hq, Hkv, D, dtype=jnp.float32, salt=0):
    ks = jax.random.split(jax.random.fold_in(KEY, salt), 3)
    q = jax.random.normal(ks[0], (B, 1, Hq, D), dtype)
    kp = jax.random.normal(ks[1], (NUM_BLOCKS, BS, Hkv, D), dtype)
    vp = jax.random.normal(ks[2], (NUM_BLOCKS, BS, Hkv, D), dtype)
    return q, kp, vp


def _tables(lens, salt=0):
    """Disjoint scrambled block tables; -1 garbage past each allocation."""
    rng = np.random.RandomState(salt)
    perm = list(rng.permutation(NUM_BLOCKS))
    bt = np.full((len(lens), MAX_BLOCKS), -1, np.int32)
    for b, L in enumerate(lens):
        nblk = -(-L // BS) if L else 0
        bt[b, :nblk] = [perm.pop() for _ in range(nblk)]
    return bt


def _gathered(kp, bt, b, L):
    nblk = -(-L // BS)
    return np.asarray(kp)[bt[b, :nblk]].reshape(-1, *kp.shape[2:])[:L]


@pytest.mark.parametrize("Hq,Hkv", [(4, 4), (8, 2)])  # MHA, GQA
@pytest.mark.parametrize("L", [1, 15, 16, 17, 64, 127, 128])
def test_paged_decode_matches_ref_window(Hq, Hkv, L):
    B, D = 2, 32
    q, kp, vp = _pool(B, Hq, Hkv, D, salt=L)
    bt = _tables([L] * B, salt=L)
    out = paged_decode(q, kp, vp, jnp.asarray(bt),
                       jnp.full((B,), L, jnp.int32), interpret=True)
    for b in range(B):
        kc = jnp.asarray(_gathered(kp, bt, b, L))[None]
        vc = jnp.asarray(_gathered(vp, bt, b, L))[None]
        want = ref.attention_ref(q[b:b + 1], kc, vc, causal=True)
        np.testing.assert_allclose(np.asarray(out[b:b + 1]),
                                   np.asarray(want), atol=2e-6, rtol=2e-6)


def test_paged_decode_mixed_lengths_and_idle():
    q, kp, vp = _pool(4, 8, 2, 64, salt=99)
    lens = [1, 40, 0, 128]               # slot 2 idle
    bt = _tables(lens, salt=99)
    out = paged_decode(q, kp, vp, jnp.asarray(bt),
                       jnp.asarray(lens, jnp.int32), interpret=True)
    assert float(jnp.abs(out[2]).max()) == 0.0  # idle emits exact zeros
    for b, L in enumerate(lens):
        if L == 0:
            continue
        kc = jnp.asarray(_gathered(kp, bt, b, L))[None]
        vc = jnp.asarray(_gathered(vp, bt, b, L))[None]
        want = ref.attention_ref(q[b:b + 1], kc, vc, causal=True)
        np.testing.assert_allclose(np.asarray(out[b:b + 1]),
                                   np.asarray(want), atol=2e-6, rtol=2e-6)


def test_paged_ref_oracle_matches_contiguous_gather():
    """The gather oracle (what CPU serving runs) equals decode_ref on a
    cache rebuilt in logical order."""
    q, kp, vp = _pool(3, 8, 2, 32, salt=7)
    lens = [7, 33, 128]
    bt = _tables(lens, salt=7)
    out = ref.paged_decode_ref(q, kp, vp, jnp.asarray(bt),
                               jnp.asarray(lens, jnp.int32))
    for b, L in enumerate(lens):
        kc = jnp.asarray(_gathered(kp, bt, b, L))[None]
        vc = jnp.asarray(_gathered(vp, bt, b, L))[None]
        want = ref.attention_ref(q[b:b + 1], kc, vc, causal=True)
        np.testing.assert_allclose(np.asarray(out[b:b + 1]),
                                   np.asarray(want), atol=2e-5, rtol=2e-5)


def test_shared_prefix_blocks_between_sequences():
    """Two sequences may alias the same physical blocks (post-fork shared
    prefix): both must read the shared content correctly."""
    q, kp, vp = _pool(2, 4, 4, 32, salt=3)
    bt = np.full((2, MAX_BLOCKS), -1, np.int32)
    bt[0, :2] = [5, 9]
    bt[1, :3] = [5, 9, 17]               # shares blocks 5, 9 with seq 0
    lens = [32, 40]
    out = paged_decode(q, kp, vp, jnp.asarray(bt),
                       jnp.asarray(lens, jnp.int32), interpret=True)
    for b, L in enumerate(lens):
        kc = jnp.asarray(_gathered(kp, bt, b, L))[None]
        vc = jnp.asarray(_gathered(vp, bt, b, L))[None]
        want = ref.attention_ref(q[b:b + 1], kc, vc, causal=True)
        np.testing.assert_allclose(np.asarray(out[b:b + 1]),
                                   np.asarray(want), atol=2e-6, rtol=2e-6)


def test_ops_attention_routes_paged_impls():
    """ops.attention with block_tables: every impl spelling lands on a
    table-walking path (kernel or oracle), and they agree; the window
    mask / table walk can never be dropped."""
    q, kp, vp = _pool(2, 4, 2, 32, salt=21)
    lens = jnp.asarray([20, 100], jnp.int32)
    bt = jnp.asarray(_tables([20, 100], salt=21))
    o_kernel = ops.attention(q, kp, vp, lengths=lens, block_tables=bt,
                             impl="pallas_interpret")
    o_ref = ops.attention(q, kp, vp, lengths=lens, block_tables=bt,
                          impl="ref")
    o_auto = ops.attention(q, kp, vp, lengths=lens, block_tables=bt,
                           impl="auto")
    o_decode = ops.attention(q, kp, vp, lengths=lens, block_tables=bt,
                             impl="decode_ref")
    np.testing.assert_allclose(np.asarray(o_kernel), np.asarray(o_ref),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_array_equal(np.asarray(o_auto), np.asarray(o_ref))
    np.testing.assert_array_equal(np.asarray(o_decode), np.asarray(o_ref))
    with pytest.raises(ValueError):
        ops.attention(q, kp, vp, block_tables=bt)  # tables need lengths


# ----------------------------------------------------- chunked prefill
def _prefill_case(B, Sq, Hq, Hkv, D, offs, true_lens, salt):
    ks = jax.random.split(jax.random.fold_in(KEY, salt), 3)
    q = jax.random.normal(ks[0], (B, Sq, Hq, D), jnp.float32)
    kp = jax.random.normal(ks[1], (NUM_BLOCKS, BS, Hkv, D), jnp.float32)
    vp = jax.random.normal(ks[2], (NUM_BLOCKS, BS, Hkv, D), jnp.float32)
    lens = [o + t for o, t in zip(offs, true_lens)]
    bt = _tables(lens, salt=salt)
    return q, kp, vp, bt, np.asarray(lens, np.int32), np.asarray(
        offs, np.int32)


@pytest.mark.parametrize("Hq,Hkv", [(4, 4), (8, 2)])  # MHA, GQA
@pytest.mark.parametrize("Sq,offs", [
    (5, [0, 8]),      # chunk from scratch / block-aligned offset
    (8, [3, 16]),     # mid-block offset / chunk == block size
    (1, [7, 0]),      # 1-token chunk (budget smaller than one block)
    (16, [16, 40]),   # chunk spanning multiple blocks
])
def test_paged_prefill_matches_ref(Hq, Hkv, Sq, offs):
    """paged_prefill kernel == gather oracle == attention_ref composed on
    each sequence's gathered visible window (queries [s, e) vs keys
    [0, e), causal by absolute position)."""
    B, D = 2, 32
    true_lens = [Sq, Sq]
    q, kp, vp, bt, lens, qoff = _prefill_case(
        B, Sq, Hq, Hkv, D, offs, true_lens, salt=Sq * 31 + offs[0]
    )
    from repro.kernels.paged_decode import paged_prefill

    o_k = paged_prefill(q, kp, vp, jnp.asarray(bt), jnp.asarray(lens),
                        jnp.asarray(qoff), interpret=True)
    o_r = ref.paged_prefill_ref(q, kp, vp, jnp.asarray(bt),
                                jnp.asarray(lens), jnp.asarray(qoff))
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               atol=2e-5, rtol=2e-5)
    for b in range(B):
        e = int(lens[b])
        kc = jnp.asarray(_gathered(kp, bt, b, e))[None]
        vc = jnp.asarray(_gathered(vp, bt, b, e))[None]
        want = ref.attention_ref(q[b:b + 1], kc, vc, causal=True)
        np.testing.assert_allclose(np.asarray(o_k[b:b + 1]),
                                   np.asarray(want), atol=2e-5, rtol=2e-5)


def test_paged_prefill_padded_tail_is_harmless():
    """Bucket-padded tail queries (true chunk shorter than Sq) produce
    garbage the caller discards — but the REAL rows must be exact and
    finite everywhere (no NaN from fully-masked rows)."""
    B, Sq, Hq, Hkv, D = 2, 8, 4, 2, 32
    true_lens = [5, 3]
    offs = [8, 0]
    q, kp, vp, bt, lens, qoff = _prefill_case(
        B, Sq, Hq, Hkv, D, offs, true_lens, salt=77
    )
    from repro.kernels.paged_decode import paged_prefill

    o_k = paged_prefill(q, kp, vp, jnp.asarray(bt), jnp.asarray(lens),
                        jnp.asarray(qoff), interpret=True)
    o_r = ref.paged_prefill_ref(q, kp, vp, jnp.asarray(bt),
                                jnp.asarray(lens), jnp.asarray(qoff))
    assert np.isfinite(np.asarray(o_k)).all()
    for b in range(B):
        np.testing.assert_allclose(
            np.asarray(o_k[b, : true_lens[b]]),
            np.asarray(o_r[b, : true_lens[b]]), atol=2e-5, rtol=2e-5,
        )


def test_ops_attention_routes_paged_prefill():
    """q_offset (or Sq > 1 with tables) routes every impl spelling to a
    chunked-prefill path; Sq > 1 without q_offset is an error, as is
    lengths with Sq > 1 and no tables."""
    B, Sq, Hq, Hkv, D = 2, 4, 4, 2, 32
    q, kp, vp, bt, lens, qoff = _prefill_case(
        B, Sq, Hq, Hkv, D, [8, 3], [Sq, Sq], salt=13
    )
    kw = dict(lengths=jnp.asarray(lens), block_tables=jnp.asarray(bt),
              q_offset=jnp.asarray(qoff))
    o_ref = ops.attention(q, kp, vp, impl="ref", **kw)
    o_kernel = ops.attention(q, kp, vp, impl="pallas_interpret", **kw)
    o_auto = ops.attention(q, kp, vp, impl="auto", **kw)
    o_dec = ops.attention(q, kp, vp, impl="decode_ref", **kw)
    np.testing.assert_allclose(np.asarray(o_kernel), np.asarray(o_ref),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_array_equal(np.asarray(o_auto), np.asarray(o_ref))
    np.testing.assert_array_equal(np.asarray(o_dec), np.asarray(o_ref))
    with pytest.raises(ValueError):
        ops.attention(q, kp, vp, lengths=jnp.asarray(lens),
                      block_tables=jnp.asarray(bt))   # Sq>1 needs q_offset
    with pytest.raises(ValueError):
        ops.attention(q, kp, vp, lengths=jnp.asarray(lens))  # no tables


def test_paged_prefill_q_offset_one_token_equals_decode():
    """A 1-token chunk at offset L-1 computes the same attention as a
    decode step at cache length L-1 (window L): the two kernels must
    agree on their shared boundary case."""
    B, Hq, Hkv, D = 2, 4, 2, 32
    L = [21, 64]
    q, kp, vp = _pool(B, Hq, Hkv, D, salt=5)
    bt = _tables(L, salt=5)
    lens = jnp.asarray(L, jnp.int32)
    o_dec = ref.paged_decode_ref(q, kp, vp, jnp.asarray(bt), lens)
    o_pre = ref.paged_prefill_ref(q, kp, vp, jnp.asarray(bt), lens,
                                  lens - 1)
    np.testing.assert_allclose(np.asarray(o_dec), np.asarray(o_pre),
                               atol=2e-5, rtol=2e-5)


def test_paged_block_kv_table():
    from repro.core.autotune import paged_block_kv

    assert paged_block_kv(4096, 64) == 64
    assert paged_block_kv(4096, 128) == 32
    assert paged_block_kv(4096, 256) == 16
    assert paged_block_kv(32, 64) == 32      # clamped to the cache cap
    bk = paged_block_kv(96, 64)              # non-power-of-two cap
    assert 96 % bk == 0
