"""Pipeline parallelism == sequential forward (4 stages, subprocess)."""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import AxisType
from repro.dist.pipeline import pipeline_forward, split_layers_into_stages

L, D, M, MB = 8, 16, 6, 4
key = jax.random.key(0)
Ws = jax.random.normal(key, (L, D, D)) * 0.3
x = jax.random.normal(jax.random.fold_in(key, 1), (M, MB, D))

def apply_layers(Ws, h):
    for i in range(Ws.shape[0]):
        h = jax.nn.relu(h @ Ws[i])
    return h

# sequential reference
ref = jnp.stack([apply_layers(Ws, x[m]) for m in range(M)])

mesh = jax.make_mesh((4,), ("stage",), axis_types=(AxisType.Auto,))
stages = split_layers_into_stages({"w": Ws}, 4)
out = pipeline_forward(
    lambda p, h: apply_layers(p["w"], h), stages, x, mesh, axis="stage"
)
err = float(jnp.abs(out - ref).max())
print("RESULT" + json.dumps({"err": err}))
"""


@pytest.mark.slow
def test_pipeline_matches_sequential():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        env=env, timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][-1]
    assert json.loads(line[len("RESULT"):])["err"] < 1e-5
