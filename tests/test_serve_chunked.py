"""Chunked-prefill edge cases: chunk boundaries vs block boundaries,
chunk budgets smaller than one block, decode-only steps between two
chunks of the same request, and preemption of a half-prefilled sequence
— all while greedy outputs stay token-identical to the contiguous
engine (chunking is scheduling, never semantics)."""
import jax
import numpy as np

from repro.configs import ARCHS
from repro.models import init_lm
from repro.serve.engine import Engine, Request

CFG = ARCHS["tinyllama-1.1b"].smoke()
PARAMS = init_lm(jax.random.key(0), CFG)

LONG = [7, 3, 9, 2, 5, 8, 6, 4, 1, 2, 3, 4, 9, 9, 8, 7, 2, 2, 3, 3]


def _reqs(n=3, max_new=8, plen=20):
    return [Request(rid=i, prompt=(LONG * 2)[:plen] + [30 + i],
                    max_new=max_new) for i in range(n)]


def _run(engine, reqs, per_step=None):
    for r in reqs:
        engine.submit(r)
    guard = 0
    while engine.load > 0 and guard < 600:
        engine.step()
        if per_step is not None:
            per_step(engine)
        guard += 1
    assert all(r.done for r in reqs)
    return [list(r.out) for r in reqs]


KW = dict(max_slots=2, max_seq=64, pad_len=32, steps_per_sync=8)
BASE = _run(Engine(CFG, PARAMS, **KW), _reqs())


def test_chunk_on_block_boundary():
    """prefill_chunk == block_size: every chunk ends exactly where a
    block ends, so chunk scatters never straddle and the next chunk
    starts a fresh block."""
    e = Engine(CFG, PARAMS, paged=True, block_size=8, prefill_chunk=8,
               **KW)
    assert _run(e, _reqs()) == BASE
    assert e.sched.chunks_scheduled >= 3 * (21 // 8)


def test_chunk_smaller_than_block():
    """prefill_chunk < block_size: several chunks land inside ONE pool
    block (the paged_prefill q_offset path mid-block), including a
    1-token chunk budget."""
    for chunk in (3, 1):
        e = Engine(CFG, PARAMS, paged=True, block_size=8,
                   prefill_chunk=chunk, **KW)
        assert _run(e, _reqs(2)) == BASE[:2], f"chunk={chunk}"
        assert e.sched.chunks_scheduled >= 2 * (21 // max(chunk, 1))


def test_chunk_larger_than_block_unaligned():
    """Chunk spans multiple blocks and ends mid-block (21-token prefix,
    5-token chunks over 8-token blocks: boundaries at 5/10/15/20)."""
    e = Engine(CFG, PARAMS, paged=True, block_size=8, prefill_chunk=5,
               **KW)
    assert _run(e, _reqs()) == BASE


def test_decode_only_step_between_chunks():
    """token_budget == lookahead: while an older sequence decodes it owns
    the whole step budget, so a younger mid-prefill sequence must sit out
    entire decode-only steps between its chunks — and still finish with
    identical tokens."""
    short = Request(rid=0, prompt=LONG[:4], max_new=4)
    long_ = Request(rid=1, prompt=LONG + [30], max_new=8)
    e_c = Engine(CFG, PARAMS, **KW)
    base = _run(e_c, [Request(rid=0, prompt=LONG[:4], max_new=4),
                      Request(rid=1, prompt=LONG + [30], max_new=8)])
    e = Engine(CFG, PARAMS, paged=True, block_size=8, prefill_chunk=6,
               token_budget=KW["steps_per_sync"], **KW)
    progress, decoded = [], []

    def snoop(engine):
        # (long_'s prefill progress if mid-prefill else None, tokens so far)
        st = [engine.sched._prefill.get(s) for s in range(KW["max_slots"])]
        st = [list(x) for x in st if x is not None]
        progress.append(st[0][0] if st else None)
        decoded.append(engine.tokens_out)

    out = _run(e, [short, long_], per_step=snoop)
    assert out == base
    assert e.sched.chunks_scheduled >= 2
    # find a step where the long request stayed mid-prefill at the SAME
    # offset while decode emitted tokens => a decode-only step between
    # two of its chunks
    stalled = any(
        p1 is not None and p1 == p0 and d1 > d0
        for p0, p1, d0, d1 in zip(progress, progress[1:],
                                  decoded, decoded[1:])
    )
    assert stalled, (progress, decoded)


def test_preempt_half_prefilled_sequence():
    """A pool too small for the older sequence's growth plus a younger
    admission's prefill: the younger one is preempted MID-PREFILL
    (watermark, youngest-first), requeued, and restarted from scratch —
    outputs stay identical to the contiguous engine."""
    kw = dict(max_slots=2, max_seq=32, pad_len=32, steps_per_sync=8)
    mk = lambda: [Request(rid=0, prompt=LONG[:4], max_new=20),
                  Request(rid=1, prompt=LONG + [30], max_new=6)]
    base = _run(Engine(CFG, PARAMS, **kw), mk())
    # 6 blocks: both admissions fit (2 + 4 blocks with lookahead), then
    # the older sequence's decode growth hits an empty free list and must
    # preempt the youngest — which is still chunking its 21-token prefix.
    e = Engine(CFG, PARAMS, paged=True, block_size=8, num_blocks=6,
               prefill_chunk=4, **kw)
    reqs = mk()
    trace = []          # (preemptions so far, victim's output length)

    def snoop(engine):
        trace.append((engine.sched.preemptions, len(reqs[1].out)))

    out = _run(e, reqs, per_step=snoop)
    assert out == base
    assert e.sched.preemptions > 0, "pool sizing must force preemption"
    # at the first preemption the young request had produced no token =>
    # it was preempted before its prefill completed (a finished prefill
    # samples the first token immediately)
    first = next(i for i, (p, _) in enumerate(trace) if p > 0)
    assert trace[first][1] == 0, trace
    assert len(reqs[1].out) > 0            # ...but it finished eventually
    # the preempted victim's prefill state is gone and the pool drained
    assert e.sched._prefill == {}
    assert e.pool.free_blocks == e.pool.num_blocks


def test_empty_prompt_rejected_loudly():
    """Regression: an empty prompt in chunked mode used to wedge its slot
    in a zero-token prefill forever (silent livelock); submit must reject
    it up front on every engine flavor."""
    import pytest

    for kw in (dict(), dict(paged=True, block_size=8),
               dict(paged=True, block_size=8, prefill_chunk=4)):
        e = Engine(CFG, PARAMS, max_slots=1, max_seq=32, pad_len=8,
                   steps_per_sync=4, **kw)
        with pytest.raises(ValueError, match="empty prompt"):
            e.submit(Request(rid=0, prompt=[], max_new=3))
        assert e.load == 0


def test_chunked_budget_bounds_prefill_work():
    """Acceptance: per-step prefill work is bounded by the token budget —
    no engine step prefills more than token_budget positions in total,
    however long the admission (prefill_chunk deliberately set far above
    the budget so the budget is the binding clamp)."""
    budget = 8
    e = Engine(CFG, PARAMS, paged=True, block_size=8, prefill_chunk=64,
               token_budget=budget, **KW)
    per_step = {}
    orig = e._run_prefill_chunk

    def spy(slot, req, start, end, last):
        per_step[e.steps] = per_step.get(e.steps, 0) + (end - start)
        return orig(slot, req, start, end, last)

    e._run_prefill_chunk = spy
    out = _run(e, _reqs(2))
    assert out == BASE[:2]
    assert per_step, "chunks must have been scheduled"
    assert max(per_step.values()) <= budget
    # a 21-token prefix under an 8-token budget needs >= 3 chunks
    assert e.sched.chunks_scheduled >= 2 * 3
