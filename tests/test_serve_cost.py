"""Cost model + predictive balancing (DESIGN.md §16): the per-tenant
decode-length predictor must converge on a synthetic length mix (with the
prior/global cold-start fallbacks), prediction error must shrink as the
online updates land, SLO-aware admission must reorder and pace by slack,
and — the hard contract — a balancer with the predictor OFF must
reproduce today's steal/shed decisions exactly on the skewed-fabric
scenario."""
import jax
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core.lifeline import diffusion_pairs
from repro.models import init_lm
from repro.obs.slo import SLOMonitor, parse_slo_spec
from repro.serve import (CostModel, CostParams, DecodeLengthPredictor,
                         Engine, GLBReplicaBalancer, Request)

CFG = ARCHS["tinyllama-1.1b"].smoke()
PARAMS = init_lm(jax.random.key(0), CFG)


# ------------------------------------------------------------- predictor
def test_cold_start_uses_prior():
    p = CostParams(prior_decode_tokens=40.0)
    cm = CostModel(p)
    assert cm.predict_decode("anyone", max_new=128) == 40.0
    # prior is clipped into the request's feasible range
    assert cm.predict_decode("anyone", max_new=16) == 16.0
    assert cm.predictor.source("anyone") == "prior"


def test_global_fallback_before_tenant_history():
    pred = DecodeLengthPredictor(CostParams(min_samples=3))
    for _ in range(5):
        pred.observe("veteran", 10)
    # a brand-new tenant answers from the pooled global histogram
    assert pred.source("newcomer") == "global"
    assert pred.predict("newcomer") == pytest.approx(10.0, abs=3.0)
    assert pred.source("veteran") == "tenant"


def test_predictor_converges_on_tenant_mix():
    """Synthetic per-tenant mix: short chat turns vs long completions.
    Each tenant's prediction must converge to its own distribution, not
    the pooled mean."""
    pred = DecodeLengthPredictor()
    for _ in range(20):
        pred.observe("chat", 8)
        pred.observe("long", 100)
    short, long_ = pred.predict("chat"), pred.predict("long")
    assert short < long_
    assert short == pytest.approx(8.0, abs=4.0)       # bucket resolution
    assert long_ == pytest.approx(100.0, abs=30.0)
    assert pred.samples("chat") == pred.samples("long") == 20


def test_prediction_error_shrinks_over_a_run():
    """Online loop: a tenant that always decodes 12 tokens starts at the
    prior (way off) and must be predicted near-exactly once min_samples
    finishes have landed — late-half error < early-half error."""
    cm = CostModel(CostParams(prior_decode_tokens=64.0, min_samples=3))
    for i in range(12):
        req = Request(rid=i, prompt=[1, 2, 3], max_new=96, tenant="t")
        cm.stamp(req)
        req.out = [7] * 12
        cm.observe_finish(req)
    snap = cm.snapshot()
    assert snap["cost_samples"] == 12
    assert snap["cost_late_abs_err_tokens"] \
        < snap["cost_early_abs_err_tokens"]
    # steady state: predictions are within a bucket of the truth
    assert cm.errors[-1] <= 2.0


def test_estimate_monotone_in_inputs():
    cm = CostModel()
    base = cm.estimate(64, 0, 0, "t", 96, 8)
    assert cm.estimate(128, 0, 0, "t", 96, 8) > base     # longer prompt
    assert cm.estimate(64, 32, 0, "t", 96, 8) < base     # warmer cache
    assert base > 0.0
    # a running request is cheaper than a queued one (prefill sunk)
    assert cm.estimate(64, 0, 10, "t", 96, 8) < base


def test_stamp_survives_resubmit():
    cm = CostModel()
    req = Request(rid=0, prompt=[1], max_new=32, tenant="t")
    first = cm.stamp(req)
    cm.predictor.observe("t", 5)
    cm.predictor.observe("t", 5)
    cm.predictor.observe("t", 5)
    assert cm.stamp(req) == first          # steal re-submit keeps stamp
    assert cm.predictions == 1


def test_cost_params_validation():
    with pytest.raises(ValueError):
        CostParams(quantile=1.5)
    with pytest.raises(ValueError):
        CostParams(us_per_decode_token=0.0)
    with pytest.raises(ValueError):
        CostParams(min_samples=0)


# ------------------------------------------------------------- diffusion
def test_diffusion_pairs_deterministic_and_balanced():
    assert diffusion_pairs([10.0, 1.0, 1.0, 1.0], 0.25) == [(0, 1)]
    assert diffusion_pairs([1.0, 1.0, 1.0], 0.25) == []
    assert diffusion_pairs([0.0, 0.0], 0.25) == []       # empty fabric
    # two donors, two recipients: richest donor gets poorest recipient
    pairs = diffusion_pairs([10.0, 8.0, 1.0, 2.0], 0.25)
    assert pairs == [(0, 2), (1, 3)]
    # ineligible recipients are skipped
    assert diffusion_pairs([10.0, 1.0, 1.0], 0.25,
                           [True, False, True]) == [(0, 2)]


# ------------------------------------------------------ SLO admission
def _slo_engine(**kw):
    slo = SLOMonitor(parse_slo_spec(kw.pop("spec", "ttft_ms=1000000")))
    return Engine(CFG, PARAMS, max_slots=kw.pop("max_slots", 2),
                  max_seq=64, pad_len=8, steps_per_sync=4, paged=True,
                  block_size=8, num_blocks=24, slo=slo,
                  slo_admission=True, **kw), slo


def test_slo_admission_requires_monitor_and_target():
    with pytest.raises(ValueError):
        Engine(CFG, PARAMS, paged=True, block_size=8, max_seq=64,
               slo_admission=True)                       # no monitor
    with pytest.raises(ValueError):
        slo = SLOMonitor(parse_slo_spec("tpot_ms=50"))   # wrong metric
        Engine(CFG, PARAMS, paged=True, block_size=8, max_seq=64,
               slo=slo, slo_admission=True)
    with pytest.raises(ValueError):
        Engine(CFG, PARAMS, slo_admission=True)          # not paged


def test_slo_admission_orders_by_slack():
    """With one slot free, the request whose TTFT budget is most blown
    is admitted first even though it arrived last."""
    eng, _ = _slo_engine(max_slots=1)
    reqs = [Request(rid=i, prompt=[1 + i] * 4, max_new=20)
            for i in range(3)]
    for r in reqs:
        eng.submit(r)
    # rid 2 has been waiting "forever": most negative slack
    reqs[2].t_queued -= 1e12
    eng.step()
    assert eng.slots[0] is not None and eng.slots[0].rid == 2


def test_slo_admission_paces_relaxed_tail():
    """Non-urgent admissions are paced to one per step while work runs;
    the deferral is counted and the deferred request is admitted on a
    later step — pacing delays, never starves."""
    eng, _ = _slo_engine(max_slots=4)
    r0 = Request(rid=0, prompt=[9] * 4, max_new=8)
    eng.submit(r0)
    eng.step()                               # r0 running
    assert any(s is not None for s in eng.slots)
    late = [Request(rid=i, prompt=[i] * 4, max_new=4) for i in (1, 2, 3)]
    for r in late:
        eng.submit(r)
    eng.step()
    assert eng.sched.paced_deferrals >= 1
    assert len(eng.queue) >= 1               # relaxed tail still queued
    while eng.load > 0 and eng.steps < 60:
        eng.step()
    assert all(r.done for r in [r0] + late)  # nobody starved


def test_fifo_admission_unchanged_without_flag():
    """Reactive-parity at the scheduler level: no flag, strict FIFO."""
    eng = Engine(CFG, PARAMS, max_slots=1, max_seq=64, pad_len=8,
                 steps_per_sync=4, paged=True, block_size=8,
                 num_blocks=24)
    reqs = [Request(rid=i, prompt=[1 + i] * 4, max_new=20)
            for i in range(3)]
    for r in reqs:
        eng.submit(r)
    reqs[2].t_queued -= 1e12                 # would be urgent under SLO
    eng.step()
    assert eng.slots[0] is not None and eng.slots[0].rid == 0


# --------------------------------------------- skewed-fabric scenarios
def _skew_fabric(cost_model=None, predictive=False, n=6):
    engines = [Engine(CFG, PARAMS, max_slots=2, max_seq=64, pad_len=16,
                      steps_per_sync=4, paged=True, block_size=8,
                      num_blocks=16, prefix_cache=True, prefill_chunk=8,
                      cost_model=cost_model)
               for _ in range(2)]
    bal = GLBReplicaBalancer(engines, migrate=True,
                             cost_model=cost_model, predictive=predictive)
    reqs = [Request(rid=i, prompt=[1 + i] * 12, max_new=10,
                    tenant=f"t{i % 2}") for i in range(n)]
    for r in reqs:
        bal.submit(r, rr=0)                  # everything on replica 0
    return bal, reqs


def test_reactive_parity_predictor_off():
    """THE regression gate: attaching a cost model with predictive=False
    must reproduce the plain balancer's steal/shed decisions exactly on
    the skewed fabric — same decision log, same supersteps, same
    outputs."""
    plain, plain_reqs = _skew_fabric()
    assert plain.run() == "terminated"
    parity, parity_reqs = _skew_fabric(cost_model=CostModel())
    assert parity.run() == "terminated"
    assert parity.decisions == plain.decisions
    assert parity.supersteps == plain.supersteps
    assert parity.diffusion_moves == 0
    assert ([r.out for r in parity_reqs]
            == [r.out for r in plain_reqs])
    # ... while the model itself DID observe the run
    assert len(parity.cost_model.errors) == len(parity_reqs)


def test_predictive_moves_before_starvation():
    """Predictive mode diffuses queued work off the overloaded replica
    proactively, terminates in no more supersteps than reactive, and
    keeps greedy outputs identical."""
    reactive, r_reqs = _skew_fabric()
    assert reactive.run() == "terminated"
    predictive, p_reqs = _skew_fabric(cost_model=CostModel(),
                                      predictive=True)
    assert predictive.run() == "terminated"
    assert predictive.diffusion_moves > 0
    assert predictive.supersteps <= reactive.supersteps
    assert ([r.out for r in p_reqs] == [r.out for r in r_reqs])


def test_predictive_requires_cost_model():
    engines = [Engine(CFG, PARAMS, max_slots=2, max_seq=64, paged=True,
                      block_size=8)]
    with pytest.raises(ValueError):
        GLBReplicaBalancer(engines, predictive=True)


def test_predictive_load_vector_and_report():
    bal, _ = _skew_fabric(cost_model=CostModel(), predictive=True)
    costs = bal._fabric_costs()
    assert costs[0] > 0.0 and costs[1] == 0.0    # all work on replica 0
    assert bal.run() == "terminated"
    merged = bal.collect()
    assert merged["_balancer"]["diffusion_moves"] == bal.diffusion_moves
    assert merged["_cost"]["cost_samples"] > 0
    assert "predictive:" in bal.report()


def test_request_cost_credits_prefix_cache():
    """The same queued request is cheaper on a replica whose radix cache
    already holds its prefix."""
    eng = Engine(CFG, PARAMS, max_slots=2, max_seq=64, pad_len=16,
                 steps_per_sync=4, paged=True, block_size=8,
                 num_blocks=24, prefix_cache=True, prefill_chunk=8,
                 cost_model=CostModel())
    shared = [5] * 16
    warm = Request(rid=0, prompt=shared, max_new=6)
    eng.submit(warm)
    while eng.load > 0 and eng.steps < 40:
        eng.step()
    assert warm.done
    again = Request(rid=1, prompt=shared, max_new=6)
    cold = Request(rid=2, prompt=[9] * 16, max_new=6)
    assert eng.prefix_cache.hit_length(eng._prefix_tokens(again)) > 0
    assert (eng.request_cost(again, True)
            < eng.request_cost(cold, True))
