"""Host-sync-free engine steps: the jitted fori_loop multi-step decode must
emit exactly what the legacy per-token loop emits (greedy), honor per-slot
budgets and cache-length caps, thread PRNG keys deterministically for
temperature sampling, and cut host drains by ~steps_per_sync."""
import jax
import numpy as np

from repro.configs import ARCHS
from repro.models import init_lm
from repro.serve.engine import Engine, Request

CFG = ARCHS["tinyllama-1.1b"].smoke()
PARAMS = init_lm(jax.random.key(0), CFG)


def _reqs(n=5, max_new=10):
    return [Request(rid=i, prompt=[3, i + 1, 4, 2], max_new=max_new)
            for i in range(n)]


def _run(engine, reqs, step):
    for r in reqs:
        engine.submit(r)
    guard = 0
    while engine.load > 0 and guard < 500:
        step()
        guard += 1
    assert all(r.done for r in reqs)
    return [list(r.out) for r in reqs]


def test_multi_step_matches_legacy_greedy():
    """N-token fori_loop decode must produce token-identical outputs to the
    per-token loop (same prefill, same greedy argmax, same cache math)."""
    e_old = Engine(CFG, PARAMS, max_slots=2, max_seq=64, pad_len=8,
                   steps_per_sync=1)
    out_old = _run(e_old, _reqs(), e_old.step_legacy)
    e_new = Engine(CFG, PARAMS, max_slots=2, max_seq=64, pad_len=8,
                   steps_per_sync=8)
    out_new = _run(e_new, _reqs(), e_new.step)
    assert out_old == out_new
    # budget contract: prefill token + exactly max_new decode tokens
    assert all(len(o) == 11 for o in out_new)


def test_host_syncs_reduced_by_steps_per_sync():
    e1 = Engine(CFG, PARAMS, max_slots=2, max_seq=64, pad_len=8,
                steps_per_sync=1)
    _run(e1, _reqs(), e1.step)
    e8 = Engine(CFG, PARAMS, max_slots=2, max_seq=64, pad_len=8,
                steps_per_sync=8)
    _run(e8, _reqs(), e8.step)
    assert e8.tokens_out == e1.tokens_out
    assert e8.host_syncs < e1.host_syncs / 3


def test_cache_length_cap_frees_slot():
    """A request whose budget exceeds the cache stops at max_seq - 1."""
    e = Engine(CFG, PARAMS, max_slots=1, max_seq=16, pad_len=8,
               steps_per_sync=4)
    req = Request(rid=0, prompt=[1, 2, 3, 4, 5, 6, 7, 8], max_new=1000)
    out = _run(e, [req], e.step)[0]
    # prompt fills 8 cache rows; decode stops once lens hits 15:
    # 1 prefill token + 7 decode tokens
    assert len(out) == 8
    assert e.lens[0] == -1 and e.slots[0] is None


def test_temperature_sampling_deterministic_in_seed():
    outs = []
    for seed in (0, 0, 1):
        e = Engine(CFG, PARAMS, max_slots=2, max_seq=64, pad_len=8,
                   steps_per_sync=4, temperature=1.0, seed=seed)
        outs.append(_run(e, _reqs(2, 12), e.step))
    assert outs[0] == outs[1], "same seed must replay the same tokens"
    assert outs[0] != outs[2], "different seed must explore differently"


def test_prefill_row_cache_isolated_between_requests():
    """The preallocated row cache is reused across admissions; a second
    request must decode exactly as if it had a fresh cache (greedy run
    twice in different admission orders must agree per-rid)."""
    a = _reqs(4, 8)
    e1 = Engine(CFG, PARAMS, max_slots=1, max_seq=64, pad_len=8,
                steps_per_sync=4)
    out_serial = _run(e1, a, e1.step)  # one slot: strictly sequential reuse
    b = _reqs(4, 8)
    e2 = Engine(CFG, PARAMS, max_slots=4, max_seq=64, pad_len=8,
                steps_per_sync=4)
    out_batch = _run(e2, b, e2.step)   # all four admitted on a zeroed pool
    assert out_serial == out_batch
