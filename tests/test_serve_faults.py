"""Replica-crash fault tolerance (DESIGN.md §15): heartbeat detection,
lifeline re-wiring, recompute re-admission — plus the balancer
accounting fixes that rode along (sterile steals, move-counter split,
wedge reporting).

One chaos harness (``repro.serve.faults.FaultInjector``), two workload
shapes: the serving fabric (``GLBReplicaBalancer``) and the taskbag
simulator (``run_sim(faults=...)``). The headline invariants, asserted
by the crash-at-every-superstep sweep:

  * the fabric still terminates (no wedge, no silent loss);
  * every submitted request finishes — the ledger re-admits the dead
    replica's queued AND running sequences;
  * re-admitted sequences are greedy-token-identical to a clean run
    (recompute migration replays the surviving ``req.out`` prefix);
  * no surviving lifeline ever references the dead place.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core import GLB, GLBParams, rewire_lifelines, run_sim
from repro.core.lifeline import lifeline_buddies
from repro.problems.bc import bc_problem
from repro.problems.fib import fib_problem, fib_oracle
from repro.problems.uts import uts_oracle, uts_problem
from repro.serve.engine import Engine, GLBReplicaBalancer, Request
from repro.serve.faults import Fault, FaultInjector

CFG = ARCHS["tinyllama-1.1b"].smoke()
_P = {}


def _params():
    if "p" not in _P:
        from repro.models import init_lm
        _P["p"] = init_lm(jax.random.key(0), CFG)
    return _P["p"]


PROMPT16 = [7, 3, 9, 2, 5, 8, 6, 4, 1, 2, 3, 4, 9, 9, 8, 7]
KW = dict(max_slots=2, max_seq=64, pad_len=16, steps_per_sync=4)


def _fabric(n=3, faults=None, tracer=None, heartbeat_misses=None, **over):
    kw = dict(paged=True, block_size=8, num_blocks=64, **KW)
    kw.update(over)
    engines = [Engine(CFG, _params(), replica_id=i, tracer=tracer, **kw)
               for i in range(n)]
    bal = GLBReplicaBalancer(engines, migrate=True, faults=faults,
                             tracer=tracer,
                             heartbeat_misses=heartbeat_misses)
    return engines, bal


def _reqs(n=4, max_new=6):
    return [Request(rid=i, prompt=list(PROMPT16), max_new=max_new)
            for i in range(n)]


_CLEAN = {}


def _clean_outputs(n_req=4, max_new=6):
    """Outputs of an identical fabric with no faults (cached)."""
    key = (n_req, max_new)
    if key not in _CLEAN:
        _, bal = _fabric()
        reqs = _reqs(n_req, max_new)
        for r in reqs:
            bal.submit(r)
        assert bal.run(max_steps=300) == "terminated"
        _CLEAN[key] = [list(r.out) for r in reqs]
    return _CLEAN[key]


# ------------------------------------------------------------- injector
def test_fault_injector_semantics():
    inj = FaultInjector().crash(0, at=2).hang(1, at=1, duration=2) \
                         .slow(2, at=0, factor=3)
    inj.begin_superstep(0)
    assert inj.responsive(0) and inj.should_step(0)
    assert inj.responsive(2) and inj.should_step(2)       # slow: step 0
    inj.begin_superstep(1)
    assert not inj.responsive(1) and not inj.should_step(1)
    assert inj.responsive(2) and not inj.should_step(2)   # slow skips
    inj.begin_superstep(2)
    assert not inj.responsive(0)                          # crashed
    assert not inj.responsive(1)                          # still hung
    inj.begin_superstep(3)
    assert not inj.responsive(0)                          # crash is forever
    assert inj.responsive(1) and inj.should_step(1)       # hang resumed
    assert inj.responsive(2) and inj.should_step(2)       # slow: step 3
    assert {(f.kind, f.place) for f in inj.fired} == {
        ("crash", 0), ("hang", 1), ("slow", 2)}
    with pytest.raises(ValueError):
        Fault("meteor", 0, 0)
    with pytest.raises(ValueError):
        Fault("slow", 0, 0, factor=1)


# ------------------------------------------------------ lifeline rewire
@pytest.mark.parametrize("dead", [(3,), (0, 5), (1, 2, 6, 7)])
def test_rewire_lifelines_invariants(dead):
    P, z = 8, 3
    alive = np.ones(P, bool)
    alive[list(dead)] = False
    bud = rewire_lifelines(alive, z)
    assert bud.shape == (P, z)
    surv = set(np.flatnonzero(alive).tolist())
    for p in range(P):
        if p in surv:
            assert set(bud[p].tolist()) <= surv      # only survivors
            assert p not in bud[p]                   # never self (S > 1)
        else:
            assert set(bud[p].tolist()) == {p}       # dead rows inert
    # connectivity over survivors: z-hypercube edges reach everyone
    reach = {min(surv)}
    for _ in range(P):
        reach |= {int(b) for p in reach for b in bud[p]}
    assert reach == surv


def test_rewire_lifelines_edge_cases():
    # sole survivor self-points (inert but well-formed)
    alive = np.array([False, True, False, False])
    assert rewire_lifelines(alive, 2).tolist()[1] == [1, 1]
    with pytest.raises(ValueError):
        rewire_lifelines(np.zeros(4, bool), 2)
    # no deaths == the static table
    np.testing.assert_array_equal(
        rewire_lifelines(np.ones(8, bool), 3), lifeline_buddies(8, 3))


# ------------------------------------------------- the headline: sweep
@pytest.mark.parametrize("crash_at", [0, 1, 2, 4])
def test_crash_sweep_no_request_lost(crash_at):
    """Crash replica 0 at superstep ``crash_at``: the fabric must
    terminate with every request finished, greedy-token-identical to a
    clean run, and no surviving lifeline referencing the dead place."""
    engines, bal = _fabric(faults=FaultInjector().crash(0, at=crash_at))
    # long enough that the victim's work is still in flight when the
    # 3-miss window expires (steps_per_sync=4 tokens per engine step)
    reqs = _reqs(max_new=24)
    for r in reqs:
        bal.submit(r)
    assert bal.run(max_steps=300) == "terminated"
    assert bal.terminated
    assert bal.replicas_dead == 1
    assert not bal.alive[0]
    assert all(r.done for r in reqs)                      # zero lost
    assert [list(r.out) for r in reqs] == _clean_outputs(4, 24)
    bud = np.asarray(bal._buddies)
    for p in np.flatnonzero(bal.alive):
        assert 0 not in bud[p], "survivor lifeline points at the corpse"
    assert not np.asarray(bal._pending)[0].any()
    assert not np.asarray(bal._pending)[:, 0].any()
    # the ledger balances: everything submitted is accounted done
    assert set(bal._ledger) == {r.rid for r in reqs}
    assert bal.readmitted_queued + bal.readmitted_running >= 1


def test_crash_readmits_queued_requests():
    """Crash before the victim ever steps: its casualties are all still
    queued and come back via plain re-submission (tier-1 recovery)."""
    engines, bal = _fabric(faults=FaultInjector().crash(0, at=0))
    reqs = _reqs()
    for r in reqs:
        bal.submit(r)
    assert bal.run(max_steps=300) == "terminated"
    assert all(r.done for r in reqs)
    assert bal.readmitted_queued >= 1
    assert [list(r.out) for r in reqs] == _clean_outputs()


def test_hang_shorter_than_window_recovers():
    """A 2-superstep hang under the default 3-miss window is absorbed:
    nobody is declared dead and nothing is re-admitted."""
    engines, bal = _fabric(faults=FaultInjector().hang(0, at=1, duration=2))
    reqs = _reqs()
    for r in reqs:
        bal.submit(r)
    assert bal.run(max_steps=300) == "terminated"
    assert bal.replicas_dead == 0
    assert bal.readmitted_queued == bal.readmitted_running == 0
    assert all(bal.alive)
    assert all(r.done for r in reqs)
    assert [list(r.out) for r in reqs] == _clean_outputs()


def test_slow_replica_not_declared_dead():
    """Slow is a compute property, not a liveness one: the place answers
    every gather, so the detector must leave it alone (specificity)."""
    engines, bal = _fabric(faults=FaultInjector().slow(0, at=0, factor=3))
    reqs = _reqs()
    for r in reqs:
        bal.submit(r)
    assert bal.run(max_steps=600) == "terminated"
    assert bal.replicas_dead == 0
    assert all(r.done for r in reqs)
    assert [list(r.out) for r in reqs] == _clean_outputs()


def test_zombie_is_fenced_after_declaration():
    """A hang LONGER than the window is declared dead; when the place
    'wakes up' it must stay fenced — a zombie double-producing tokens
    would corrupt the fabric (its work was already re-admitted)."""
    engines, bal = _fabric(faults=FaultInjector().hang(0, at=1, duration=8),
                           heartbeat_misses=2)
    reqs = _reqs()
    for r in reqs:
        bal.submit(r)
    assert bal.run(max_steps=300) == "terminated"
    assert bal.replicas_dead == 1
    assert not bal.alive[0]                    # still fenced
    assert all(r.done for r in reqs)
    assert [list(r.out) for r in reqs] == _clean_outputs()
    # the hang is long over; the place answers gathers again — but a
    # declared death is permanent: new work routes around the zombie
    # and it is never stepped again
    steps0 = engines[0].steps
    late = Request(rid=99, prompt=list(PROMPT16), max_new=4)
    bal.submit(late)
    assert bal.run(max_steps=300) == "terminated"
    assert late.done
    assert not bal.alive[0]
    assert engines[0].steps == steps0


def test_running_readmission_needs_compatible_host():
    """A running casualty can only recompute-land on a paged survivor
    with headroom; a fabric whose only survivor can't host must fail
    loudly, not drop the request."""
    tr = None
    victim = Engine(CFG, _params(), replica_id=0, paged=True, block_size=8,
                    num_blocks=64, tracer=tr, **KW)
    survivor = Engine(CFG, _params(), replica_id=1, tracer=tr, **KW)  # legacy
    bal = GLBReplicaBalancer([victim, survivor], migrate=True,
                             faults=FaultInjector().crash(0, at=2))
    req = Request(rid=0, prompt=list(PROMPT16), max_new=40)
    bal.submit(req, rr=0)                  # pin to the doomed replica
    victim.step()                          # now RUNNING in a slot
    with pytest.raises(RuntimeError, match="no surviving paged"):
        bal.run(max_steps=100)


def test_all_replicas_dead_raises():
    engines, bal = _fabric(n=2, faults=FaultInjector().crash(0, at=0)
                                                      .crash(1, at=0))
    bal.submit(Request(rid=0, prompt=list(PROMPT16), max_new=4))
    with pytest.raises(RuntimeError):
        bal.run(max_steps=100)


# --------------------------------------------- satellite 1: sterile steal
def test_incompatible_thief_no_sterile_steal():
    """_stealable must advertise only what the present thieves can host:
    a victim whose sequences exceed every thief's max_seq produces NO
    match at all, not a sterile one (pre-fix: matched every round,
    moved nothing, moves counter still climbed)."""
    victim = Engine(CFG, _params(), replica_id=0, paged=True, block_size=8,
                    num_blocks=64, max_slots=2, max_seq=64, pad_len=16,
                    steps_per_sync=4)
    thief = Engine(CFG, _params(), replica_id=1, paged=True, block_size=8,
                   num_blocks=64, max_slots=2, max_seq=32, pad_len=16,
                   steps_per_sync=4)
    bal = GLBReplicaBalancer([victim, thief], migrate=True)
    for i in range(2):
        bal.submit(Request(rid=i, prompt=list(PROMPT16), max_new=40), rr=0)
    for _ in range(6):                     # grow written past thief's 32
        victim.step()
    for _ in range(8):
        bal.balance()
        victim.step()
    assert bal.sterile_steals == 0
    assert bal.migrations == 0
    assert bal.moves == 0


# ------------------------------------------- satellite 2: counter split
def test_move_counter_split_and_report():
    """moves == queue_moves + migrations, the trace's per-tier counts
    agree, and the report spells the split out."""
    from repro.obs import Tracer
    from repro.obs.analyze import analyze_trace, check_invariants
    tr = Tracer()
    engines, bal = _fabric(n=2, tracer=tr, block_size=8, num_blocks=32,
                           max_seq=32, pad_len=8)
    for i in range(6):
        engines[0].submit(Request(rid=i, prompt=[3, i + 1, 4, 2],
                                  max_new=8))
    assert bal.run(max_steps=200) == "terminated"
    assert bal.moves == bal.queue_moves + bal.migrations
    assert bal.moves > 0
    a = analyze_trace(tr)
    assert check_invariants(a) == []
    assert a.steal.tier1_moves == bal.queue_moves
    assert a.steal.tier2_moves == bal.migrations
    if a.steal.tier1_rounds:
        assert a.steal.tier1_moves_per_round > 0
    assert "queued" in bal.report()


# --------------------------------------------- satellite 3: wedge status
def test_run_returns_wedged_and_traces_it():
    from repro.obs import Tracer
    from repro.obs.analyze import analyze_trace
    tr = Tracer()
    engines, bal = _fabric(n=1, tracer=tr)
    bal.submit(Request(rid=0, prompt=list(PROMPT16), max_new=40))
    assert bal.run(max_steps=2) == "wedged"
    assert not bal.terminated
    assert analyze_trace(tr).steal.wedged
    # a fresh fabric that drains reports success
    engines2, bal2 = _fabric(n=1)
    bal2.submit(Request(rid=0, prompt=list(PROMPT16), max_new=4))
    assert bal2.run(max_steps=300) == "terminated"


# -------------------------------------------------- analyzer attribution
def test_analyzer_recovery_attribution():
    """A crash trace analyzes clean: the re-admitted request carries a
    readmissions count and a 'recovering' bucket, the steal report sees
    the death, and the invariant checker stays green."""
    from repro.obs import Tracer
    from repro.obs.analyze import analyze_trace, check_invariants
    tr = Tracer()
    engines, bal = _fabric(tracer=tr,
                           faults=FaultInjector().crash(0, at=1))
    reqs = _reqs()
    for r in reqs:
        bal.submit(r)
    assert bal.run(max_steps=300) == "terminated"
    a = analyze_trace(tr)
    assert check_invariants(a) == []
    assert a.steal.replicas_dead == 1
    total_readmit = bal.readmitted_queued + bal.readmitted_running
    assert a.steal.readmissions == total_readmit >= 1
    readmitted = [r for r in a.requests if r.readmissions > 0]
    assert len(readmitted) == total_readmit
    assert not a.steal.wedged
    d = a.to_dict()
    assert d["steal"]["replicas_dead"] == 1
    from repro.obs.analyze import render_markdown, render_summary
    assert "failures" in render_markdown(a)
    assert "failures" in render_summary(a)


# --------------------------------------------------- taskbag sim chaos
def test_sim_fib_crash_exact():
    """fib survives a mid-run crash with the exact same answer: the dead
    place's bag is drained wholesale into the survivors."""
    prob = fib_problem(16)
    want = int(run_sim(prob, 4, GLBParams(n=16, steal_k=16), seed=0)
               .result)
    got = run_sim(prob, 4, GLBParams(n=16, steal_k=16), seed=0,
                  faults=FaultInjector().crash(1, at=2))
    assert int(got.result) == want == fib_oracle(16)
    assert bool(got.converged)


def test_sim_uts_crash_at_root_holder():
    """Crash place 0 — the root holder — after it has expanded a bit:
    its remaining subtree must migrate and the count stays exact."""
    prob = uts_problem(depth=5)
    want = int(run_sim(prob, 4, GLBParams(n=32, steal_k=16), seed=0)
               .result)
    got = run_sim(prob, 4, GLBParams(n=32, steal_k=16), seed=0,
                  faults=FaultInjector().crash(0, at=2))
    assert int(got.result) == want == uts_oracle(depth=5)


def test_sim_bc_crash_evacuates_in_state_vertex():
    """BC holds an in-progress vertex in state (§2.6's interruptable
    state machine); evacuate() re-bags it so the crash loses nothing."""
    from repro.problems.rmat import rmat_graph
    adj, n = rmat_graph(scale=4, seed=7)
    prob = bc_problem(adj, capacity=256)
    want = np.asarray(run_sim(prob, 4, GLBParams(n=4, steal_k=8),
                              seed=0).result)
    got = run_sim(prob, 4, GLBParams(n=4, steal_k=8), seed=0,
                  faults=FaultInjector().crash(2, at=3))
    np.testing.assert_allclose(np.asarray(got.result), want,
                               rtol=1e-4, atol=1e-4)


def test_sim_hang_shorter_than_window_is_absorbed():
    prob = fib_problem(14)
    clean = run_sim(prob, 4, GLBParams(n=16, steal_k=16), seed=0)
    got = run_sim(prob, 4, GLBParams(n=16, steal_k=16), seed=0,
                  faults=FaultInjector().hang(1, at=1, duration=2))
    assert int(got.result) == int(clean.result)


def test_sim_faults_require_evacuate_hook():
    """A problem with in-state work but no evacuate hook cannot be run
    under fault injection — its mid-item window isn't survivable."""
    from repro.problems.rmat import rmat_graph
    adj, _ = rmat_graph(scale=4, seed=7)
    prob = dataclasses.replace(bc_problem(adj, capacity=256),
                               evacuate=None)
    with pytest.raises(ValueError, match="evacuate"):
        run_sim(prob, 4, GLBParams(n=4), seed=0,
                faults=FaultInjector().crash(1, at=1))
    # ...and GLB.run forwards the injector only in sim mode
    glb = GLB(fib_problem(12), GLBParams(n=16), P=2)
    assert int(glb.run(seed=0, faults=FaultInjector().crash(1, at=50))) \
        == fib_oracle(12)


def test_sim_all_places_dead_raises():
    prob = fib_problem(14)
    with pytest.raises(RuntimeError, match="died"):
        run_sim(prob, 2, GLBParams(n=4, steal_k=4), seed=0,
                faults=FaultInjector().crash(0, at=0).crash(1, at=0))
