"""Cross-implementation identity matrix: under greedy sampling every
serving engine — legacy per-token loop, contiguous fori_loop fast path,
paged, paged + prefix cache (with and without chunked prefill) — must
emit exactly the same tokens, across several registry architectures
(dense, dense+qkv-bias, MoE — not just the one cfg earlier PRs pinned),
including a forced-eviction run where a cached prefix is reclaimed under
pool pressure and transparently recomputed.

The MoE arch runs with a raised capacity_factor (dropless): with
capacity-bounded dispatch a token's output depends on which OTHER slots
share its decode step (drops are batch-global), so exact cross-engine
identity is only well-defined when nothing is dropped — the router,
sort-dispatch, and paged-attention stack are still fully exercised."""
import dataclasses

import jax
import pytest

from repro.configs import ARCHS
from repro.models import init_lm
from repro.serve.engine import Engine, Request

# dense / dense+qkv_bias / MoE — three distinct attention+ffn stacks
MATRIX_ARCHS = ["tinyllama-1.1b", "qwen2-1.5b", "moonshot-v1-16b-a3b"]

_PARAMS = {}


def _setup(arch):
    if arch not in _PARAMS:
        cfg = ARCHS[arch].smoke()
        if cfg.family == "moe":
            cfg = dataclasses.replace(cfg, capacity_factor=8.0)
        _PARAMS[arch] = (cfg, init_lm(jax.random.key(0), cfg))
    return _PARAMS[arch]


SHARED = [7, 3, 9, 2, 5, 8, 6, 4, 1, 2, 3, 4]   # 12-token system prompt


def _reqs(n=4, max_new=8):
    return [
        Request(rid=i, prompt=SHARED + [10 + i, 11, 12 + i % 3],
                max_new=max_new + i % 3)
        for i in range(n)
    ]


def _run(engine, reqs, step=None):
    step = step or engine.step
    for r in reqs:
        engine.submit(r)
    guard = 0
    while engine.load > 0 and guard < 600:
        step()
        guard += 1
    assert all(r.done for r in reqs)
    return [list(r.out) for r in reqs]


KW = dict(max_slots=2, max_seq=64, pad_len=16, steps_per_sync=4)


@pytest.fixture(scope="module", params=MATRIX_ARCHS)
def baseline(request):
    cfg, params = _setup(request.param)
    e = Engine(cfg, params, **KW)
    return request.param, _run(e, _reqs(), e.step_legacy)


def test_contiguous_fast_matches_legacy(baseline):
    arch, base = baseline
    cfg, params = _setup(arch)
    assert _run(Engine(cfg, params, **KW), _reqs()) == base


def test_paged_matches_legacy(baseline):
    arch, base = baseline
    cfg, params = _setup(arch)
    e = Engine(cfg, params, paged=True, block_size=8, **KW)
    assert _run(e, _reqs()) == base
    assert e.pool.free_blocks == e.pool.num_blocks


def test_paged_prefix_cache_matches_legacy(baseline):
    """Hit + miss paths: the first wave misses and seeds the radix tree,
    the second wave hits the shared prompt's cached blocks — and both
    waves' outputs are token-identical to the legacy engine."""
    arch, base = baseline
    cfg, params = _setup(arch)
    e = Engine(cfg, params, paged=True, block_size=8, prefix_cache=True,
               **KW)
    assert _run(e, _reqs()) == base
    assert e.prefix_cache.misses > 0
    hits0 = e.prefix_cache.hits
    second = _reqs()
    for r in second:
        r.rid += 100
    assert _run(e, second) == base
    assert e.prefix_cache.hits > hits0, "second wave must hit the cache"
    assert e.prefix_cache.tokens_reused >= 8
    # all seq refs dropped: everything left is reclaimable cache
    assert (e.pool.free_blocks + e.pool.cached_blocks
            == e.pool.num_blocks)


def test_paged_prefix_cache_chunked_matches_legacy(baseline):
    arch, base = baseline
    cfg, params = _setup(arch)
    e = Engine(cfg, params, paged=True, block_size=8, prefix_cache=True,
               prefill_chunk=4, token_budget=8, **KW)
    assert _run(e, _reqs()) == base
    assert e.sched.chunks_scheduled >= len(_reqs())


def test_forced_live_migration_matches_legacy(baseline):
    """A sequence yanked mid-decode from one replica and re-materialized
    block-for-block on another (Engine.migrate_out -> migrate_in) must
    emit exactly the tokens an uninterrupted single-engine run does —
    across the same arch matrix as every other engine variant."""
    arch, base = baseline
    cfg, params = _setup(arch)
    e0 = Engine(cfg, params, paged=True, block_size=8, **KW)
    e1 = Engine(cfg, params, paged=True, block_size=8, **KW)
    reqs = _reqs()
    for r in reqs:
        e0.submit(r)
    e0.step()                          # admit 2, decode a burst: mid-decode
    cands = e0.migratable_slots()
    assert cands, "a running slot must be sheddable"
    mode = e1.migrate_in(e0.migrate_out(cands[0]))
    assert mode == "live", f"KV must move intact, got {mode!r}"
    guard = 0
    while (e0.load > 0 or e1.load > 0) and guard < 600:
        e0.step()
        e1.step()
        guard += 1
    assert all(r.done for r in reqs)
    assert [list(r.out) for r in reqs] == base
    assert e1.migrations_in == 1 and e0.migrations_out == 1


def test_partial_hit_that_cannot_fit_falls_back_to_miss():
    """Regression: a mid-block cache hit whose fork would pin the very
    blocks the availability check counted as reclaimable used to pass
    admission, fail in reserve, and retry the queue head forever (and
    inflate hit stats every retry). The scheduler must instead admit the
    request as a plain miss — evicting the cached prefix — and finish."""
    cfg, params = _setup("tinyllama-1.1b")
    kw = dict(max_slots=1, max_seq=32, pad_len=16, steps_per_sync=16)
    pa = SHARED                                  # 12 tokens
    pb = SHARED[:10] + [90, 91]                  # mid-block divergence
    mk = lambda: [Request(rid=0, prompt=list(pa), max_new=5),
                  Request(rid=1, prompt=list(pb), max_new=5)]
    base = _run(Engine(cfg, params, **kw), mk())
    # 4 blocks total: A's release caches 2 blocks; B's hit-credited
    # admission needs 3 fresh blocks but pinning the 2 matched blocks
    # leaves only 2 available — the credited path cannot fit.
    e = Engine(cfg, params, paged=True, block_size=8, num_blocks=4,
               prefix_cache=True, **kw)
    reqs = mk()
    out = _run(e, reqs)                          # must not livelock
    assert out == base
    assert e.prefix_cache.evictions > 0          # miss path evicted A
    assert e.prefix_cache.hits == 0
    assert e.prefix_cache.tokens_reused == 0     # stats stay honest
    assert e.pool.free_blocks + e.pool.cached_blocks == e.pool.num_blocks


def test_forced_eviction_recomputes_transparently(baseline):
    """A pool sized so that caching request A's blocks leaves too little
    for B's growth: B's admission/reservation must evict A's cached
    prefix (reclaimable accounting), and a later request with A's prompt
    misses and recomputes — token-identical throughout."""
    arch, base = baseline
    cfg, params = _setup(arch)
    kw = dict(KW, max_slots=1, max_seq=32)
    eb = Engine(cfg, params, **kw)
    base3 = _run(eb, _reqs(3), eb.step_legacy)
    e = Engine(cfg, params, paged=True, block_size=8, num_blocks=4,
               prefix_cache=True, **kw)
    assert _run(e, _reqs(3)) == base3
    assert e.prefix_cache.evictions > 0, \
        "pool sizing must force cache eviction"
