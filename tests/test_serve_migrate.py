"""Live KV migration between replica engines (DESIGN.md §9): the
lifeline protocol's "steal work in progress" applied to serving.

Covers the Migration ownership contract (migrate_out frees the victim,
migrate_in must land every sequence somewhere), the three landing modes
(live / radix-seeded / recompute) each preserving greedy token identity,
mid-prefill rejection, the shed policies, the balancer's two-tier steal
order (queue first, live sequences only when the victim's queue is empty
but its slots are saturated), GLB termination detection, and fabric-level
result collection."""
import jax
import pytest

from repro.configs import ARCHS
from repro.core import merge_place_stats, terminated
from repro.serve.engine import Engine, GLBReplicaBalancer, Request
from repro.serve.kvpool import KVPool, PoolExhausted

CFG = ARCHS["tinyllama-1.1b"].smoke()
_P = {}


def _params():
    if "p" not in _P:
        from repro.models import init_lm
        _P["p"] = init_lm(jax.random.key(0), CFG)
    return _P["p"]


PROMPT16 = [7, 3, 9, 2, 5, 8, 6, 4, 1, 2, 3, 4, 9, 9, 8, 7]
KW = dict(max_slots=2, max_seq=64, pad_len=16, steps_per_sync=4)


def _legacy_baseline(reqs):
    e = Engine(CFG, _params(), **KW)
    for r in reqs:
        e.submit(r)
    guard = 0
    while e.load > 0 and guard < 600:
        e.step_legacy()
        guard += 1
    assert all(r.done for r in reqs)
    return [list(r.out) for r in reqs]


def _drain(*engines, guard=600):
    while any(e.load > 0 for e in engines) and guard > 0:
        for e in engines:
            e.step()
        guard -= 1
    assert guard > 0, "fabric failed to drain"


# ------------------------------------------------------------ pool extract
def test_extract_inject_roundtrip_pool_level():
    """extract names exactly the written blocks (lookahead reservations
    excluded); inject re-registers the sequence atomically on a peer."""
    pool = KVPool(8, 4)
    pool.alloc(1, 10)                       # 3 blocks written
    pool.reserve(1, 14)                     # +1 lookahead block
    blocks, written = pool.extract(1)
    assert written == 10
    assert blocks == pool.block_table(1)[:3]
    peer = KVPool(8, 4)
    table = peer.inject(1, 10)
    assert len(table) == 3 and peer.seq_len(1) == 10
    tiny = KVPool(2, 4)
    with pytest.raises(PoolExhausted):
        tiny.inject(7, 12)                  # needs 3 > 2 blocks
    assert tiny.free_blocks == 2            # atomic: nothing leaked


# ------------------------------------------------------- mid-prefill guard
def test_mid_prefill_slot_cannot_migrate():
    """A half-prefilled slot owns half-written blocks and a chunk plan;
    it is excluded from shed_candidates and migrate_out rejects it."""
    e = Engine(CFG, _params(), paged=True, block_size=8, prefill_chunk=4,
               token_budget=4, **KW)
    e.submit(Request(rid=0, prompt=list(PROMPT16), max_new=5))
    e.step()                                # first chunk only (budget 4)
    assert e.sched.mid_prefill(0)
    assert e.migratable_slots() == []
    with pytest.raises(ValueError):
        e.migrate_out(0)
    _drain(e)


# --------------------------------------------------------- fallback modes
BLOCKER_PROMPT = [9, 8, 7, 6, 5, 4, 3, 2, 1, 2, 3, 4, 5]   # 13 tokens


def _wedged_victim(steps=7, max_new=30):
    """One long-running sequence mid-decode on a paged engine: after
    ``steps`` bursts of 4 its written length is 16 + 4*steps (44 by
    default — 6 pool blocks)."""
    e = Engine(CFG, _params(), paged=True, block_size=8,
               **dict(KW, max_slots=1))
    req = Request(rid=0, prompt=list(PROMPT16), max_new=max_new)
    e.submit(req)
    for _ in range(steps):
        e.step()
    assert not req.done
    return e, req


def _tight_thief(**extra):
    """Thief (8-block pool) where a blocker pins 3 blocks at migration
    time (written 17, capacity 24 tokens) and then finishes WITHOUT ever
    reserving another block — so the pool is tight when the migrant
    arrives, but nothing later forces an eviction of seeded blocks, and
    the pool drains naturally for the resume admission."""
    e = Engine(CFG, _params(), paged=True, block_size=8, num_blocks=8,
               **dict(KW, max_slots=2), **extra)
    blocker = Request(rid=50, prompt=list(BLOCKER_PROMPT), max_new=8)
    e.submit(blocker)
    e.step()                    # lens 17, 3 blocks held, 5 free
    assert not blocker.done
    assert e.pool.available_blocks == 5
    return e, blocker


def test_pool_exhausted_falls_back_to_recompute():
    base = _legacy_baseline([Request(rid=0, prompt=list(PROMPT16),
                                     max_new=30),
                             Request(rid=50, prompt=list(BLOCKER_PROMPT),
                                     max_new=8)])
    victim, req = _wedged_victim()
    thief, blocker = _tight_thief()
    mig = victim.migrate_out(victim.migratable_slots()[0])
    assert mig.written == 44                # needs 6 blocks > 5 available
    mode = thief.migrate_in(mig)
    assert mode == "recompute"
    assert thief.queue and thief.queue[0] is req   # front of the queue
    _drain(victim, thief)
    assert [list(req.out), list(blocker.out)] == base
    assert thief.migrations_recompute == 1


def test_radix_seeded_resume():
    """When the whole sequence cannot fit, the thief plants however many
    full blocks DO fit in its radix cache, and the recompute admission
    hits the planted prefix instead of re-prefilling from scratch."""
    base = _legacy_baseline([Request(rid=0, prompt=list(PROMPT16),
                                     max_new=30),
                             Request(rid=50, prompt=list(BLOCKER_PROMPT),
                                     max_new=8)])
    victim, req = _wedged_victim()
    thief, blocker = _tight_thief(prefix_cache=True)
    mig = victim.migrate_out(victim.migratable_slots()[0])
    mode = thief.migrate_in(mig)
    assert mode == "seeded"
    assert thief.migrations_seeded == 1
    assert thief.migrations_recompute == 0   # seeded is NOT a recompute
    assert thief.prefix_cache.seeded_tokens >= 8
    hits0 = thief.prefix_cache.hits
    _drain(victim, thief)
    assert thief.prefix_cache.hits > hits0, \
        "resume admission must hit the seeded prefix"
    assert thief.prefix_cache.tokens_reused >= 8
    assert [list(req.out), list(blocker.out)] == base


def test_migration_between_block_size_mismatch_recomputes():
    """Different pool geometries cannot exchange raw blocks; the move
    degrades to resume-by-recompute, never to corruption."""
    base = _legacy_baseline([Request(rid=0, prompt=list(PROMPT16),
                                     max_new=30)])
    victim, req = _wedged_victim()
    thief = Engine(CFG, _params(), paged=True, block_size=16, **KW)
    mode = thief.migrate_in(victim.migrate_out(0))
    assert mode == "recompute"
    _drain(victim, thief)
    assert [list(req.out)] == base


def test_migration_longer_than_thief_capacity_is_refused():
    """A sequence whose cache prefix cannot fit the thief's max_seq can
    never decode there (live landing would overflow _device_tables, a
    recompute requeue would crash the thief's admission): migrate_in
    refuses outright — ownership stays with the caller — and the
    balancer's can_host pre-filter never sheds to such a thief."""
    victim, req = _wedged_victim()          # written 44
    thief = Engine(CFG, _params(), paged=True, block_size=8,
                   **dict(KW, max_seq=32))  # can host < 32 cache tokens
    assert not thief.can_host(44)
    mig = victim.migrate_out(0)
    with pytest.raises(ValueError):
        thief.migrate_in(mig)
    # the Migration still owns the request; the victim can take it back
    victim._requeue_migrated(req)
    _drain(victim)
    assert req.done


def test_balancer_skips_incompatible_thief():
    """_steal_live's can_host filter: a saturated victim facing a thief
    with a smaller max_seq keeps its sequences instead of crashing."""
    victim = Engine(CFG, _params(), paged=True, block_size=8, **KW)
    thief = Engine(CFG, _params(), paged=True, block_size=8,
                   **dict(KW, max_seq=32))
    bal = GLBReplicaBalancer([victim, thief], migrate=True)
    reqs = [Request(rid=i, prompt=list(PROMPT16), max_new=40)
            for i in range(2)]
    for r in reqs:
        bal.submit(r, rr=0)
    for _ in range(6):
        victim.step()                   # written grows past thief max_seq
    assert all(int(victim.lens[s]) >= 32 for s in range(2))
    bal.run(max_steps=200)
    assert bal.migrations == 0          # nothing compatible to shed
    assert all(r.done for r in reqs)


# ----------------------------------------------------------- shed policy
def test_shed_policy_orders_candidates():
    def mk(policy):
        e = Engine(CFG, _params(), paged=True, block_size=8,
                   shed_policy=policy, **KW)
        e.submit(Request(rid=0, prompt=list(PROMPT16), max_new=20))
        e.submit(Request(rid=1, prompt=list(PROMPT16), max_new=6))
        e.step()
        return e
    young = mk("youngest")
    # slot 1 (rid 1) admitted last => youngest-first leads with it
    assert young.migratable_slots()[0] == 1
    budget = mk("budget")
    # rid 0 has far more budget left => budget policy leads with slot 0
    assert budget.migratable_slots()[0] == 0
    with pytest.raises(AssertionError):
        Engine(CFG, _params(), paged=True, block_size=8,
               shed_policy="bogus", **KW)
    _drain(young, budget)


# ------------------------------------------------------ two-tier balancer
def test_balancer_steals_queue_before_live_sequences():
    """A victim with queued requests sheds its queue (tier 1); live
    sequences move only when the queue is empty."""
    mk = lambda: Engine(CFG, _params(), paged=True, block_size=8,
                        **dict(KW, max_slots=1))
    engines = [mk(), mk()]
    bal = GLBReplicaBalancer(engines, migrate=True)
    for i in range(3):
        bal.submit(Request(rid=i, prompt=[3, i + 1, 4], max_new=8), rr=0)
    engines[0].step()                   # 1 running + 2 queued on victim
    bal.balance()
    assert bal.moves > 0 and bal.migrations == 0, \
        "queued work must move before running work"


def test_balancer_saturated_victim_sheds_live_sequence():
    mk = lambda: Engine(CFG, _params(), paged=True, block_size=8, **KW)
    engines = [mk(), mk()]
    bal = GLBReplicaBalancer(engines, migrate=True)
    reqs = [Request(rid=i, prompt=[3, i + 1, 4], max_new=20)
            for i in range(2)]
    for r in reqs:
        bal.submit(r, rr=0)
    engines[0].step()                   # both running, queue empty
    assert engines[0].free_slots == 0 and not engines[0].queue
    bal.run(max_steps=100)
    assert bal.migrations >= 1 and bal.migration_modes["live"] >= 1
    assert all(r.done for r in reqs)
    assert engines[1].migrations_in >= 1


def test_balancer_migrate_off_never_moves_live():
    mk = lambda: Engine(CFG, _params(), paged=True, block_size=8, **KW)
    engines = [mk(), mk()]
    bal = GLBReplicaBalancer(engines)   # migrate defaults off
    reqs = [Request(rid=i, prompt=[3, i + 1, 4], max_new=20)
            for i in range(2)]
    for r in reqs:
        bal.submit(r, rr=0)
    engines[0].step()
    bal.run(max_steps=100)
    assert bal.migrations == 0
    assert all(r.done for r in reqs)


# ------------------------------------- termination + result collection
def test_termination_via_size_vector_and_result_collection():
    assert terminated([0, 0, 0]) and not terminated([0, 2, 0])
    mk = lambda: Engine(CFG, _params(), paged=True, block_size=8, **KW)
    engines = [mk(), mk(), mk()]
    bal = GLBReplicaBalancer(engines, migrate=True)
    reqs = [Request(rid=i, prompt=[3, i + 1, 4], max_new=6 + i % 4)
            for i in range(7)]
    for r in reqs:
        bal.submit(r, rr=0)
    bal.run(max_steps=200)
    assert bal.terminated, "balance pass must detect the all-zero loads"
    assert all(r.done for r in reqs)
    merged = bal.collect()
    assert merged["tokens_out"]["total"] == sum(
        e.tokens_out for e in engines
    )
    assert merged["_balancer"]["supersteps"] == bal.supersteps
    assert "moves" in merged["_balancer"]
    report = bal.report()
    assert "replica fabric: 3 places" in report
    assert "terminated=True" in report


def test_merge_place_stats_heterogeneous_fields():
    merged = merge_place_stats([{"a": 1, "b": 2}, {"a": 3}])
    assert merged["a"] == {"total": 4.0, "mean": 2.0, "max": 3.0,
                           "argmax": 1}
    assert merged["b"]["total"] == 2.0 and merged["b"]["argmax"] == 0
