"""Paged serving contract: the paged engine (block pool + scheduler +
paged decode) must emit exactly what the contiguous engine emits under
greedy sampling — across mixed per-slot lengths, forced preemption and
resume, and per-step token budgets — while packing more sequences into
the same cache memory. Plus the balancer satellites: FIFO steals and
capacity-based hunger."""
import jax
import numpy as np

from repro.configs import ARCHS
from repro.models import init_lm
from repro.serve.engine import Engine, GLBReplicaBalancer, Request

CFG = ARCHS["tinyllama-1.1b"].smoke()
PARAMS = init_lm(jax.random.key(0), CFG)


def _reqs(n=5, max_new=10):
    # mixed budgets => mixed final lengths across slots
    return [Request(rid=i, prompt=[3, i + 1, 4, 2], max_new=max_new + i % 4)
            for i in range(n)]


def _run(engine, reqs):
    for r in reqs:
        engine.submit(r)
    guard = 0
    while engine.load > 0 and guard < 500:
        engine.step()
        guard += 1
    assert all(r.done for r in reqs)
    return [list(r.out) for r in reqs]


def _contiguous_baseline(reqs_fn, **kw):
    e = Engine(CFG, PARAMS, **kw)
    return _run(e, reqs_fn())


def test_paged_matches_contiguous_greedy():
    kw = dict(max_slots=2, max_seq=64, pad_len=8, steps_per_sync=8)
    out_c = _contiguous_baseline(_reqs, **kw)
    e_p = Engine(CFG, PARAMS, paged=True, **kw)
    out_p = _run(e_p, _reqs())
    assert out_c == out_p
    # everything released: pool drains to empty
    assert e_p.pool.free_blocks == e_p.pool.num_blocks
    assert e_p.sched.preemptions == 0


def test_paged_preempt_and_resume_token_identical():
    """A pool too small for both sequences' growth forces watermark
    preemption; resume-by-recompute must keep greedy outputs identical
    to the never-preempted contiguous run."""
    kw = dict(max_slots=2, max_seq=32, pad_len=8, steps_per_sync=8)
    out_c = _contiguous_baseline(lambda: _reqs(5, 14), **kw)
    e_t = Engine(CFG, PARAMS, paged=True, block_size=8, num_blocks=5, **kw)
    out_t = _run(e_t, _reqs(5, 14))
    assert e_t.sched.preemptions > 0, "pool sizing must force preemption"
    assert out_t == out_c
    assert e_t.pool.free_blocks == 5


def test_watermark_starved_pool_stays_live():
    """Regression: a sole sequence whose growth collides with the
    watermark must keep decoding via partial reservations (it must never
    preempt itself into a permanent admit/preempt loop)."""
    kw = dict(max_slots=2, max_seq=64, pad_len=8, steps_per_sync=8)
    req_c = Request(rid=0, prompt=[3, 1, 4, 2], max_new=60)
    e_c = Engine(CFG, PARAMS, **kw)
    out_c = _run(e_c, [req_c])
    # pool of exactly max_blocks, watermark 1: full lookahead reservation
    # is impossible near max_seq.
    e_p = Engine(CFG, PARAMS, paged=True, block_size=8, num_blocks=8,
                 watermark_blocks=1, **kw)
    req_p = Request(rid=0, prompt=[3, 1, 4, 2], max_new=60)
    out_p = _run(e_p, [req_p])
    assert out_p == out_c


def test_paged_token_budget_paces_slots():
    """token_budget < slots * steps_per_sync pauses the youngest slots
    each step without changing any sequence's tokens."""
    kw = dict(max_slots=2, max_seq=32, pad_len=8, steps_per_sync=8)
    out_c = _contiguous_baseline(lambda: _reqs(4, 12), **kw)
    e_b = Engine(CFG, PARAMS, paged=True, token_budget=8, **kw)
    out_b = _run(e_b, _reqs(4, 12))
    assert out_b == out_c


def test_paged_packs_more_sequences_at_fixed_memory():
    """With the same number of KV rows, the paged engine runs more
    sequences concurrently than the contiguous engine has slots."""
    max_seq, rows = 64, 4 * 64            # contiguous: 4 slots x 64 rows
    e_c = Engine(CFG, PARAMS, max_slots=4, max_seq=max_seq, pad_len=8,
                 steps_per_sync=4)
    reqs_c = _reqs(12, 8)
    _run(e_c, reqs_c)
    assert e_c.peak_running == 4
    bs = 8
    e_p = Engine(CFG, PARAMS, max_slots=rows // bs, max_seq=max_seq,
                 pad_len=8, steps_per_sync=4, paged=True, block_size=bs,
                 num_blocks=rows // bs)   # same rows of KV memory
    reqs_p = _reqs(12, 8)
    _run(e_p, reqs_p)
    assert e_p.peak_running >= 2 * e_c.peak_running
    # and the tokens are still identical per request
    assert [r.out for r in reqs_p] == [r.out for r in reqs_c]


def test_scheduler_exports_occupancy():
    e = Engine(CFG, PARAMS, max_slots=2, max_seq=32, pad_len=8,
               steps_per_sync=4, paged=True, block_size=8)
    assert e.pool_occupancy == 0.0
    for r in _reqs(2, 8):
        e.submit(r)
    e.step()
    assert 0.0 < e.pool_occupancy <= 1.0
    s = e.pool.stats()
    assert s.live_blocks == s.num_blocks - s.free_blocks
    while e.load > 0:
        e.step()
    assert e.pool_occupancy == 0.0


# --------------------------------------------------------------- balancer
def test_balancer_steals_oldest_first():
    """Stolen requests must leave the victim's queue in arrival order
    (FIFO), not inverted from the tail."""
    engines = [Engine(CFG, PARAMS, max_slots=1, max_seq=32, pad_len=8,
                      steps_per_sync=4) for _ in range(2)]
    bal = GLBReplicaBalancer(engines)
    reqs = _reqs(6, 6)
    for r in reqs:
        bal.submit(r, rr=0)               # adversarial: all on replica 0
    bal.balance()
    assert bal.moves > 0
    stolen = [r.rid for r in engines[1].queue]
    assert stolen == sorted(stolen), "steals must preserve arrival order"
    remaining = [r.rid for r in engines[0].queue]
    assert remaining == sorted(remaining)
    # the thief got the OLDEST requests, not the newest
    assert stolen and stolen[0] == min(r.rid for r in reqs)


def test_balancer_hungry_on_free_capacity_not_total_idleness():
    """A replica with a running slot but spare capacity must steal; one
    with no free slots must not."""
    engines = [Engine(CFG, PARAMS, max_slots=2, max_seq=32, pad_len=8,
                      steps_per_sync=4) for _ in range(2)]
    bal = GLBReplicaBalancer(engines)
    # occupy ONE slot of replica 1 -> still hungry (a free slot remains)
    busy = Request(rid=100, prompt=[3, 5, 4, 2], max_new=30)
    engines[1].submit(busy)
    engines[1].step()
    assert engines[1].load > 0            # not idle -- old rule: not hungry
    assert engines[1].can_accept()
    for r in _reqs(6, 6):
        bal.submit(r, rr=0)
    bal.balance()
    assert bal.moves > 0, "partially-busy replica with capacity must steal"


def test_balancer_round_robin_ignores_rid_density():
    """Regression: placement used rid % P, so strided rids (all even, or
    clustered ids from an upstream sharder) piled every request onto one
    replica. The internal submission counter must spread them evenly
    regardless of rid values; the rr override still pins placement."""
    engines = [Engine(CFG, PARAMS, max_slots=1, max_seq=32, pad_len=8,
                      steps_per_sync=4) for _ in range(2)]
    bal = GLBReplicaBalancer(engines)
    for i in range(8):
        # adversarial rids: all even => rid % 2 == 0 for every request
        bal.submit(Request(rid=2 * i, prompt=[3, i + 1, 4], max_new=4))
    qs = [len(e.queue) for e in engines]
    assert qs == [4, 4], f"strided rids skewed placement: {qs}"
    bal2 = GLBReplicaBalancer(
        [Engine(CFG, PARAMS, max_slots=1, max_seq=32, pad_len=8,
                steps_per_sync=4) for _ in range(2)]
    )
    for i in range(4):
        bal2.submit(Request(rid=2 * i, prompt=[3, 1, 4], max_new=4), rr=0)
    assert [len(e.queue) for e in bal2.engines] == [4, 0]


def test_balancer_completes_all_requests_paged():
    """End-to-end: paged replicas + balancer drain an adversarial queue;
    pool pressure feeds hunger via can_accept."""
    engines = [Engine(CFG, PARAMS, max_slots=2, max_seq=32, pad_len=8,
                      steps_per_sync=4, paged=True, block_size=8)
               for _ in range(2)]
    bal = GLBReplicaBalancer(engines)
    reqs = _reqs(10, 6)
    for r in reqs:
        bal.submit(r, rr=0)
    bal.run(max_steps=300)
    assert all(r.done for r in reqs)
    assert bal.moves > 0
    assert all(e.pool.free_blocks == e.pool.num_blocks for e in engines)
