"""End-to-end behaviour tests for the paper's system claims.

The paper's evaluation (§3): UTS-G and BC-G achieve near-linear speedup,
near-perfect efficiency, and near-perfect load balance, with results
identical to the sequential computation. These are the laptop-scale
versions of those claims.
"""
import numpy as np
import pytest

from repro.core import GLBParams, run_sim
from repro.problems.bc import bc_problem
from repro.problems.rmat import rmat_graph
from repro.problems.uts import uts_oracle, uts_problem


def test_uts_efficiency_and_balance_at_8_places():
    """Paper Fig 2/3: efficiency ~1 and flat workload distribution."""
    params = GLBParams(n=256, w=2, steal_k=64)
    oracle = uts_oracle(4.0, 9, 19)
    out = run_sim(uts_problem(4.0, 9, 19), 8, params, seed=0)
    assert int(out.result) == oracle
    steps = int(out.supersteps)
    eff = oracle / (steps * 8 * params.n)
    assert eff > 0.75, f"superstep efficiency {eff:.3f} too low"
    w = np.asarray(out.stats["processed"], np.float64)
    assert w.std() / w.mean() < 0.15, "workload distribution not flat"


def test_uts_speedup_scaling():
    """Makespan (supersteps) must shrink ~linearly with places."""
    params = GLBParams(n=64, w=2, steal_k=64)
    prob = uts_problem(4.0, 8, 19)
    steps = {}
    for P in (1, 4, 16):
        out = run_sim(prob, P, params, seed=0)
        steps[P] = int(out.supersteps)
    assert steps[4] < steps[1] / 2.5, steps
    assert steps[16] < steps[4] / 2.0, steps


def test_bc_speedup_and_identical_result():
    adj, n = rmat_graph(scale=6, seed=5)
    prob = bc_problem(adj, capacity=512)
    params = GLBParams(n=4, w=2, steal_k=16)
    r1 = run_sim(prob, 1, params, seed=0)
    r8 = run_sim(prob, 8, params, seed=0)
    np.testing.assert_allclose(
        np.asarray(r1.result), np.asarray(r8.result), rtol=1e-4, atol=1e-3
    )
    assert int(r8.supersteps) < int(r1.supersteps) / 4


@pytest.mark.slow
def test_train_loop_reduces_loss():
    from repro.launch.train import train

    _, _, history = train([
        "--arch", "tinyllama-1.1b", "--preset", "tiny",
        "--steps", "60", "--batch", "8", "--seq", "64",
        "--lr", "3e-3", "--log-every", "20",
    ])
    assert history[-1]["loss"] < history[0]["loss"] - 0.3
