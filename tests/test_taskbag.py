"""Unit + property tests for the array-backed TaskBag."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _optional_hypothesis import given, settings, st

from repro.core import taskbag as tb

SPEC = {"v": jax.ShapeDtypeStruct((), jnp.int32)}


def _bag_with(values):
    bag = tb.make_bag(SPEC, 64)
    for v in values:
        bag = tb.push_one(bag, {"v": jnp.int32(v)})
    return bag


def _contents(bag):
    n = int(bag["size"])
    return list(np.asarray(bag["items"]["v"])[:n])


def test_push_pop_lifo():
    bag = _bag_with([1, 2, 3])
    bag, item = tb.pop_tail(bag)
    assert int(item["v"]) == 3
    assert _contents(bag) == [1, 2]


def test_push_block_masked_guard():
    # count=0 push into a full bag must not corrupt live rows
    bag = tb.make_bag(SPEC, 4)
    for v in range(4):
        bag = tb.push_one(bag, {"v": jnp.int32(v)})
    block = {"v": jnp.full((4,), 99, jnp.int32)}
    bag2 = tb.push_block(bag, block, jnp.int32(0))
    assert _contents(bag2) == [0, 1, 2, 3]


@settings(max_examples=30, deadline=None)
@given(
    values=st.lists(st.integers(-1000, 1000), min_size=0, max_size=40),
    k=st.integers(1, 16),
)
def test_split_merge_preserves_multiset(values, k):
    """Paper invariant: split+merge moves items, never duplicates/drops."""
    bag = _bag_with(values)
    kept, pkt = tb.split_tail_half(bag, k)
    count = int(pkt["count"])
    assert count == min((len(values) + 1) // 2, k)
    other = tb.make_bag(SPEC, 64)
    other = tb.merge_packet(other, pkt)
    merged = sorted(_contents(kept) + _contents(other))
    assert merged == sorted(values)


@settings(max_examples=20, deadline=None)
@given(valid=st.lists(st.booleans(), min_size=1, max_size=24))
def test_compact_block(valid):
    k = len(valid)
    vals = jnp.arange(k, dtype=jnp.int32)
    block = {"v": vals}
    mask = jnp.asarray(valid)
    out, count = tb.compact_block(block, mask)
    expect = [i for i, ok in enumerate(valid) if ok]
    assert int(count) == len(expect)
    assert list(np.asarray(out["v"])[: len(expect)]) == expect
    # invalid tail zeroed
    assert (np.asarray(out["v"])[len(expect):] == 0).all()


def test_split_empty_bag():
    bag = tb.make_bag(SPEC, 8)
    kept, pkt = tb.split_tail_half(bag, 4)
    assert int(pkt["count"]) == 0
    assert int(kept["size"]) == 0
