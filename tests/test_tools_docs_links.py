"""The CI docs link checker must resolve good relative links and
GitHub-style anchors, and flag dangling files/anchors — on synthetic
trees and on the repo's real docs."""
import os
import subprocess
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                "tools"))
from check_docs_links import check, slugify  # noqa: E402

REPO = os.path.join(os.path.dirname(__file__), "..")


def _tree(tmp_path, files):
    for rel, content in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(content)
    return str(tmp_path)


def test_slugify_github_rules():
    assert slugify("Predictive balancing (cost model)") \
        == "predictive-balancing-cost-model"
    assert slugify("§14 Trace analytics & SLO (obs/analyze)") \
        == "14-trace-analytics--slo-obsanalyze"
    assert slugify("`code` and **bold**") == "code-and-bold"


def test_good_links_and_anchors_pass(tmp_path):
    root = _tree(tmp_path, {
        "README.md": "# T\n[a](docs/a.md)\n[s](DESIGN.md#my-section)\n",
        "DESIGN.md": "# D\n## My section\n",
        "docs/a.md": "# A\n[back](../README.md)\n[self](#a)\n",
    })
    _, problems = check(root)
    assert problems == []


def test_dangling_file_and_anchor_flagged(tmp_path):
    root = _tree(tmp_path, {
        "README.md": "# T\n[gone](docs/missing.md)\n"
                     "[bad](DESIGN.md#no-such-heading)\n",
        "DESIGN.md": "# D\n## Real heading\n",
    })
    _, problems = check(root)
    assert len(problems) == 2
    assert any("dangling link" in p for p in problems)
    assert any("dangling anchor" in p for p in problems)


def test_code_fences_ignored(tmp_path):
    root = _tree(tmp_path, {
        "README.md": "# T\n```python\nx = d[(broken](nope.md)\n```\n",
    })
    _, problems = check(root)
    assert problems == []


def test_duplicate_headings_get_suffixes(tmp_path):
    root = _tree(tmp_path, {
        "README.md": "# T\n## Gates\n## Gates\n[g2](#gates-1)\n",
    })
    _, problems = check(root)
    assert problems == []


@pytest.mark.parametrize("as_cli", [False, True])
def test_repo_docs_resolve(as_cli):
    """The committed README/docs/DESIGN must pass their own gate."""
    if as_cli:
        r = subprocess.run(
            [sys.executable, os.path.join(REPO, "tools",
                                          "check_docs_links.py"), REPO],
            capture_output=True, text=True)
        assert r.returncode == 0, r.stderr
    else:
        _, problems = check(REPO)
        assert problems == []
