"""Docs link checker (stdlib-only, CI lint step): every relative
markdown link and ``#anchor`` fragment in README.md, DESIGN.md, and
``docs/**/*.md`` must resolve — a dangling link or a heading that was
renamed without its references fails the build.

Anchors are computed with GitHub's heading-slug rules (lowercase, strip
punctuation, spaces to hyphens, ``-N`` suffixes for duplicates), so a
link that works here works on the rendered page. External links
(``http(s)://``, ``mailto:``) are not fetched — this gate is about
*our* files agreeing with each other, offline and deterministic.

  python tools/check_docs_links.py [root]
"""
import os
import re
import sys

LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING = re.compile(r"^(#{1,6})\s+(.*?)\s*#*\s*$")
FENCE = re.compile(r"^(```|~~~)")
# GitHub slugger keeps word chars (unicode), spaces, and hyphens;
# everything else is dropped before spaces become hyphens.
SLUG_DROP = re.compile(r"[^\w\s-]", re.UNICODE)
INLINE_MD = re.compile(r"[`*]|\[([^\]]*)\]\([^)]*\)")


def slugify(heading: str) -> str:
    """GitHub-style anchor slug for one heading line's text."""
    text = INLINE_MD.sub(lambda m: m.group(1) or "", heading)
    text = SLUG_DROP.sub("", text.lower())
    return text.strip().replace(" ", "-")


def md_files(root: str):
    out = []
    for name in ("README.md", "DESIGN.md"):
        p = os.path.join(root, name)
        if os.path.exists(p):
            out.append(p)
    docs = os.path.join(root, "docs")
    for dirpath, _, names in os.walk(docs):
        out.extend(os.path.join(dirpath, n)
                   for n in sorted(names) if n.endswith(".md"))
    return out


def parse(path: str):
    """-> (anchors, links). links = [(lineno, target)]; fenced code
    blocks contribute neither (a ```python sample isn't a link)."""
    anchors, links, seen = set(), [], {}
    in_fence = False
    with open(path, encoding="utf-8") as f:
        for lineno, line in enumerate(f, 1):
            if FENCE.match(line):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            m = HEADING.match(line)
            if m:
                slug = slugify(m.group(2))
                n = seen.get(slug, 0)
                seen[slug] = n + 1
                anchors.add(slug if n == 0 else f"{slug}-{n}")
            for lm in LINK.finditer(line):
                links.append((lineno, lm.group(1)))
    return anchors, links


def check(root: str):
    files = md_files(root)
    anchors = {os.path.abspath(p): parse(p)[0] for p in files}
    problems = []
    for path in files:
        _, links = parse(path)
        base = os.path.dirname(os.path.abspath(path))
        rel = os.path.relpath(path, root)
        for lineno, target in links:
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            frag = None
            if "#" in target:
                target, frag = target.split("#", 1)
            dest = (os.path.abspath(path) if not target
                    else os.path.abspath(os.path.join(base, target)))
            if not os.path.exists(dest):
                problems.append(f"{rel}:{lineno}: dangling link "
                                f"-> {target}")
                continue
            if frag is not None:
                dest_anchors = anchors.get(dest)
                if dest_anchors is None:
                    dest_anchors = (parse(dest)[0]
                                    if dest.endswith(".md") else set())
                    anchors[dest] = dest_anchors
                if frag not in dest_anchors:
                    problems.append(
                        f"{rel}:{lineno}: dangling anchor "
                        f"-> {target or os.path.basename(dest)}#{frag}")
    return files, problems


def main() -> int:
    root = sys.argv[1] if len(sys.argv) > 1 else "."
    files, problems = check(root)
    if problems:
        print(f"docs link check: {len(problems)} problem(s)",
              file=sys.stderr)
        for p in problems:
            print(f"  {p}", file=sys.stderr)
        return 1
    n_links = sum(len(parse(p)[1]) for p in files)
    print(f"docs link check: {len(files)} file(s), {n_links} link(s), "
          f"all resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
